//! Trained TM model: clause evaluation and class sums on the Rust side.
//!
//! This mirrors the semantics of the Pallas kernel / jnp oracle exactly
//! (see `python/compile/kernels/ref.py`): a clause fires iff every included
//! literal is 1 and the clause is non-empty; class sums are signed votes.
//! The hardware simulators consume the *clause bits* (they are the PDL
//! select inputs); `class_sums` is used for functional cross-checks.
//!
//! The request path is fully packed (§Data plane, rust/README.md):
//! [`TmModel::forward_packed`] consumes a [`PackedBatch`] of feature rows
//! and emits packed fired-clause words, with class sums computed as
//! `popcount(fired & pos) − popcount(fired & neg)` over precomputed
//! class-major polarity masks — the software analogue of the paper's
//! time-domain popcount voter.
//!
//! On top of that sits the **clause-indexed hot loop** (§Data plane,
//! "The hot loop"): every clause is indexed at construction by one of
//! its included literals (the one with the lowest set-probability under
//! the provided or uniform prior — see [`TmModel::reindex_with_stats`]),
//! clauses sharing an index literal form a bucket, and a sample only
//! evaluates the buckets whose index literal it sets — a clause whose
//! index literal reads 0 cannot fire, so whole buckets are skipped
//! without touching their include words (Gorji et al., arXiv
//! 2004.03188). Clauses with no usable index literal land in a fallback
//! bucket that is scanned for every sample, so the index is
//! correctness-preserving by construction: every path below is bit-exact
//! against [`TmModel::forward_reference`].

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::util::json;

use super::bits::{
    copy_bits, is_subset, or_into, tail_mask, words_for, BitVec64, PackedBatch, WORD_BITS,
};
use super::parse_bits;

/// Output of one batched TM forward pass (mirrors `model.tm_forward` on the
/// Python side; identical layout across every backend — re-exported as
/// `runtime::ForwardOutput`, the type every [`crate::runtime::InferenceBackend`]
/// returns).
///
/// Clause bits are stored *bit-packed*: `fired` holds one `c_total`-bit
/// row per sample (class-major clause order, LSB-first `u64` words — the
/// layout of [`crate::tm::bits`]). At MNIST clause counts this is 32×
/// smaller than the old `Vec<i32>` row (1000 clauses: 16 words vs 1000
/// i32s), and it is the form the polarity-mask popcount voter consumes
/// directly. Consumers that want bools (hardware sims, goldens) go
/// through [`ForwardOutput::clause_bits_row`] / [`ForwardOutput::fired_row`].
#[derive(Debug, Clone, PartialEq)]
pub struct ForwardOutput {
    pub batch: usize,
    pub n_classes: usize,
    pub c_total: usize,
    /// (batch × n_classes) row-major signed class sums.
    pub sums: Vec<i32>,
    /// Bit-packed clause outputs: one `c_total`-bit row per sample.
    pub fired: PackedBatch,
    /// (batch) argmax predictions.
    pub pred: Vec<i32>,
}

impl ForwardOutput {
    /// An output with zero rows (identity for [`ForwardOutput::append`]).
    pub fn empty(n_classes: usize, c_total: usize) -> ForwardOutput {
        ForwardOutput {
            batch: 0,
            n_classes,
            c_total,
            sums: Vec::new(),
            fired: PackedBatch::new(c_total),
            pred: Vec::new(),
        }
    }

    /// Concatenate another output's rows onto this one (used by backends
    /// that execute a logical batch as several fixed-size chunks).
    pub fn append(&mut self, other: ForwardOutput) -> Result<()> {
        ensure!(
            self.n_classes == other.n_classes && self.c_total == other.c_total,
            "cannot append outputs of different shapes ({}/{} vs {}/{})",
            self.n_classes,
            self.c_total,
            other.n_classes,
            other.c_total
        );
        self.batch += other.batch;
        self.sums.extend(other.sums);
        self.fired.append(&other.fired)?;
        self.pred.extend(other.pred);
        Ok(())
    }

    pub fn sums_row(&self, b: usize) -> &[i32] {
        &self.sums[b * self.n_classes..(b + 1) * self.n_classes]
    }

    /// Packed fired-clause words of sample `b` (the native popcount form).
    pub fn fired_words_row(&self, b: usize) -> &[u64] {
        self.fired.row(b)
    }

    /// Flat clause bits of sample `b`, class-major (unpacked — for
    /// goldens and tests, not the hot path).
    pub fn fired_row(&self, b: usize) -> Vec<bool> {
        self.fired.row_bools(b)
    }

    /// Clause bits of sample `b`, grouped per class (PDL select inputs).
    pub fn clause_bits_row(&self, b: usize) -> Vec<Vec<bool>> {
        let per = self.c_total / self.n_classes;
        (0..self.n_classes)
            .map(|k| (k * per..(k + 1) * per).map(|c| self.fired.bit(b, c)).collect())
            .collect()
    }
}

/// Output of one batched *partial* forward pass: one clause shard's
/// contribution to a batch (see [`ClauseShard`]). Same layout as
/// [`ForwardOutput`] minus predictions — a shard cannot argmax, only the
/// reduce over all shards can — plus the shard coordinates needed to
/// prove an exact cover at merge time. `fired` rows are full
/// `c_total`-bit rows with only this shard's clause bits set, so shard
/// outputs OR together into exactly the unsharded fired rows (hardware
/// replay consumes them per shard: each shard models one voter slice,
/// and the serving layer takes the max-over-shards decision latency as
/// the critical path).
#[derive(Debug, Clone, PartialEq)]
pub struct PartialOutput {
    pub batch: usize,
    pub n_classes: usize,
    pub c_total: usize,
    /// Which shard produced this partial (`0..n_shards`).
    pub shard: usize,
    /// Total shards in the plan this partial belongs to.
    pub n_shards: usize,
    /// (batch × n_classes) row-major partial class sums — this shard's
    /// signed votes only.
    pub sums: Vec<i32>,
    /// Bit-packed clause outputs, full-width rows, shard-owned bits only.
    pub fired: PackedBatch,
}

impl PartialOutput {
    /// A partial with zero rows, ready for [`ClauseShard::partial_class_sums_into`].
    pub fn empty(n_classes: usize, c_total: usize, shard: usize, n_shards: usize) -> PartialOutput {
        PartialOutput {
            batch: 0,
            n_classes,
            c_total,
            shard,
            n_shards,
            sums: Vec::new(),
            fired: PackedBatch::new(c_total),
        }
    }

    /// Wrap a full forward output as the single shard of a 1-shard plan
    /// (the default [`crate::runtime::InferenceBackend`] partial path:
    /// an unsharded backend *is* shard 0 of 1).
    pub fn from_full(out: ForwardOutput) -> PartialOutput {
        PartialOutput {
            batch: out.batch,
            n_classes: out.n_classes,
            c_total: out.c_total,
            shard: 0,
            n_shards: 1,
            sums: out.sums,
            fired: out.fired,
        }
    }

    pub fn sums_row(&self, b: usize) -> &[i32] {
        &self.sums[b * self.n_classes..(b + 1) * self.n_classes]
    }

    /// Packed shard-local fired words of sample `b`.
    pub fn fired_words_row(&self, b: usize) -> &[u64] {
        self.fired.row(b)
    }

    /// View this partial as a [`ForwardOutput`] with *shard-local*
    /// argmax predictions (ties → lowest index). Only meaningful behind
    /// a reduce that recomputes the argmax over merged sums; exists so a
    /// shard-serving backend can satisfy the unsharded `forward`
    /// contract with its real partial data.
    pub fn into_forward_output(self) -> ForwardOutput {
        let pred = (0..self.batch).map(|b| argmax_lowest(self.sums_row(b))).collect();
        ForwardOutput {
            batch: self.batch,
            n_classes: self.n_classes,
            c_total: self.c_total,
            sums: self.sums,
            fired: self.fired,
            pred,
        }
    }
}

/// Argmax with ties resolving to the lowest index (`jnp.argmax`).
#[inline]
fn argmax_lowest(sums: &[i32]) -> i32 {
    let mut best = 0usize;
    for (k, &s) in sums.iter().enumerate() {
        if s > sums[best] {
            best = k;
        }
    }
    best as i32
}

/// Reduce one batch's shard partials into the unsharded result — the
/// pure merge half of the scatter/reduce plan. Requires an *exact
/// cover*: every shard `0..n_shards` present exactly once, all partials
/// agreeing on shape and batch size. Class sums add element-wise (each
/// clause votes in exactly one shard), fired rows OR together
/// (shard-disjoint bit sets), and predictions re-argmax over the merged
/// sums with ties still resolving to the lowest class index — bit-exact
/// with [`TmModel::forward_packed`] on the same batch, for any shard
/// count (see `tests/sharded_forward.rs`).
pub fn merge_partials(parts: &[PartialOutput]) -> Result<ForwardOutput> {
    ensure!(!parts.is_empty(), "merge_partials: no partials");
    let p0 = &parts[0];
    let (batch, k, c_total, n_shards) = (p0.batch, p0.n_classes, p0.c_total, p0.n_shards);
    ensure!(
        parts.len() == n_shards,
        "merge_partials: {} partials for an {n_shards}-shard plan",
        parts.len()
    );
    let mut seen = vec![false; n_shards];
    for p in parts {
        ensure!(
            p.batch == batch && p.n_classes == k && p.c_total == c_total,
            "merge_partials: shard {} shape ({}, {}, {}) != ({batch}, {k}, {c_total})",
            p.shard,
            p.batch,
            p.n_classes,
            p.c_total
        );
        ensure!(
            p.n_shards == n_shards && p.shard < n_shards,
            "merge_partials: shard {}/{} in an {n_shards}-shard merge",
            p.shard,
            p.n_shards
        );
        ensure!(!seen[p.shard], "merge_partials: shard {} present twice", p.shard);
        seen[p.shard] = true;
    }
    let mut out = ForwardOutput::empty(k, c_total);
    out.batch = batch;
    out.sums = vec![0i32; batch * k];
    for p in parts {
        for (acc, &s) in out.sums.iter_mut().zip(&p.sums) {
            *acc += s;
        }
    }
    let words = words_for(c_total);
    let mut row = vec![0u64; words];
    for b in 0..batch {
        row.fill(0);
        for p in parts {
            or_into(&mut row, p.fired_words_row(b));
        }
        out.fired.push_words(&row);
    }
    out.pred = (0..batch).map(|b| argmax_lowest(out.sums_row(b))).collect();
    Ok(out)
}

/// A trained multi-class TM in the interchange layout (clause axis
/// flattened class-major, literals `[x, ~x]`).
#[derive(Debug, Clone)]
pub struct TmModel {
    pub name: String,
    pub n_classes: usize,
    pub n_features: usize,
    pub clauses_per_class: usize,
    /// Include masks, one bitvec of length `2 * n_features` per clause.
    pub include: Vec<Vec<bool>>,
    /// +1 / −1 vote per clause (class-major).
    pub polarity: Vec<i8>,
    /// Clause has ≥1 include.
    pub nonempty: Vec<bool>,
    /// Training-time test accuracy (%).
    pub accuracy: f64,
    /// Bit-packed include masks in one flat, cache-contiguous arena:
    /// `include_words` words per clause, same class-major clause order —
    /// the clause-evaluation hot path reads word rows out of this single
    /// allocation (§Perf L3: ~50× over the bool-wise loop at MNIST-scale
    /// literal counts, with no per-clause `Vec` indirection).
    pub(crate) packed_include: Vec<u64>,
    /// Words per clause row of `packed_include` (`words_for(2 * n_features)`).
    pub(crate) include_words: usize,
    /// Per-class polarity masks over the packed fired-clause words
    /// (§Perf L3: class sums by word-level popcount, no per-clause loop).
    class_masks: Vec<ClassMasks>,
    /// The clause skip index (see the module docs and
    /// [`TmModel::fired_words_into_indexed`]). The bit-sliced engine
    /// (`tm::slice`) scans the same arena in the same slot order, so both
    /// forward paths share one include layout and one skip structure.
    pub(crate) clause_index: ClauseIndex,
    /// `class_ub_suffix[k]` = the largest sum any class `≥ k` can reach
    /// (its count of positive-polarity non-empty clauses; sums only lose
    /// votes from there), with an `i32::MIN` sentinel at `n_classes`.
    /// Drives the exact early-exit argmax of [`TmModel::predict_packed`].
    class_ub_suffix: Vec<i32>,
}

/// Polarity masks for one class over the flat class-major fired bit
/// space. `pos`/`neg` cover only the word span the class's clauses
/// occupy (starting at word `start`), with every bit outside the class's
/// clause range already zeroed — so the class sum is exactly
/// `Σ_w popcount(fired[start+w] & pos[w]) − popcount(fired[start+w] & neg[w])`.
#[derive(Debug, Clone)]
struct ClassMasks {
    start: usize,
    pos: Vec<u64>,
    neg: Vec<u64>,
}

/// One bucket of the clause index: the clauses (scan slots
/// `start..end`) whose chosen index literal is `lit`. When a sample's
/// literal `lit` reads 0, none of them can fire and the whole bucket is
/// skipped without touching its include words.
#[derive(Debug, Clone)]
pub(crate) struct IndexBucket {
    pub(crate) lit: u32,
    pub(crate) start: u32,
    pub(crate) end: u32,
}

/// The clause skip index, built once at model construction.
///
/// Scan slots are laid out fallback-first, then bucket-major, and
/// `arena` holds a *permuted copy* of the include rows in that same
/// order — so scanning a bucket walks memory sequentially even though
/// its clause ids are scattered across classes. `clause_of[slot]` maps a
/// scan slot back to the flat class-major clause id for the fired-bit
/// write. Clauses whose stored `nonempty` flag is false never fire and
/// are omitted entirely (their fired bits stay 0); non-empty clauses
/// with no included literal (the flag is authoritative — such a clause
/// fires on *every* sample) go to the fallback range `0..n_fallback`,
/// which is scanned unconditionally.
#[derive(Debug, Clone, Default)]
pub(crate) struct ClauseIndex {
    pub(crate) stride: usize,
    pub(crate) arena: Vec<u64>,
    pub(crate) clause_of: Vec<u32>,
    pub(crate) n_fallback: usize,
    pub(crate) buckets: Vec<IndexBucket>,
    /// Total clauses in skippable buckets (the skip-rate denominator's
    /// indexable part).
    pub(crate) n_skippable: usize,
}

/// Observable shape of a model's clause index (docs/benches/tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClauseIndexStats {
    /// Clauses in skippable buckets.
    pub indexed: usize,
    /// Clauses scanned on every sample (non-empty flag with no included
    /// literal — the correctness fallback).
    pub fallback: usize,
    /// Distinct index literals in use.
    pub buckets: usize,
}

fn build_clause_index(
    packed_include: &[u64],
    stride: usize,
    nonempty: &[bool],
    lit_one_prob: Option<&[f64]>,
) -> ClauseIndex {
    let mut fallback: Vec<u32> = Vec::new();
    let mut by_lit: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
    for (c, &live) in nonempty.iter().enumerate() {
        if !live {
            continue; // never fires; needs no scan slot at all
        }
        let row = &packed_include[c * stride..(c + 1) * stride];
        // Pick the included literal with the lowest set-probability
        // (uniform prior without stats → the lowest literal index, via
        // the strict `<`). Rarely-set index literals skip their bucket
        // on most samples.
        let mut best: Option<(f64, u32)> = None;
        for (w, &word) in row.iter().enumerate() {
            let mut word = word;
            while word != 0 {
                let lit = w * WORD_BITS + word.trailing_zeros() as usize;
                let p = lit_one_prob.map_or(0.5, |ps| ps[lit]);
                if best.is_none_or(|(bp, _)| p < bp) {
                    best = Some((p, lit as u32));
                }
                word &= word - 1;
            }
        }
        match best {
            Some((_, lit)) => by_lit.entry(lit).or_default().push(c as u32),
            None => fallback.push(c as u32),
        }
    }
    let n_fallback = fallback.len();
    let mut order = fallback;
    let mut buckets = Vec::with_capacity(by_lit.len());
    let mut n_skippable = 0usize;
    for (lit, clauses) in by_lit {
        let start = order.len() as u32;
        n_skippable += clauses.len();
        order.extend(clauses);
        buckets.push(IndexBucket { lit, start, end: order.len() as u32 });
    }
    let mut arena = vec![0u64; order.len() * stride];
    for (slot, &c) in order.iter().enumerate() {
        let c = c as usize;
        arena[slot * stride..(slot + 1) * stride]
            .copy_from_slice(&packed_include[c * stride..(c + 1) * stride]);
    }
    ClauseIndex { stride, arena, clause_of: order, n_fallback, buckets, n_skippable }
}

/// Reusable buffers + skip telemetry for the batched hot loop.
///
/// [`TmModel::forward_packed_with`] and [`TmModel::predict_packed_with`]
/// take one of these so the per-sample body allocates nothing *and* the
/// per-batch setup reuses prior capacity — workers hold one scratch per
/// backend for the lifetime of the pool (see `runtime::NativeBackend`).
/// The counters accumulate across calls; snapshot or [`ForwardScratch::reset`]
/// at whatever granularity telemetry wants.
#[derive(Debug, Default)]
pub struct ForwardScratch {
    lits: Vec<u64>,
    negated: Vec<u64>,
    fired: Vec<u64>,
    sums: Vec<i32>,
    /// Sliced-path buffers (see `tm::slice`): transposed feature planes,
    /// per-group literal planes, per-clause fired planes, re-transposed
    /// row-major fired words, and the per-class CSA vertical counters.
    /// All keep their capacity across batches, like the row-major
    /// buffers above.
    pub(crate) planes: Vec<u64>,
    pub(crate) lit_planes: Vec<u64>,
    pub(crate) fired_planes: Vec<u64>,
    pub(crate) fired_rows: Vec<u64>,
    pub(crate) csa_pos: Vec<super::slice::CsaAccumulator>,
    pub(crate) csa_neg: Vec<super::slice::CsaAccumulator>,
    /// Rows evaluated through this scratch.
    pub rows: u64,
    /// Clauses the index skipped without evaluation.
    pub clauses_skipped: u64,
    /// Clauses an unindexed scan would have evaluated (`rows × c_total`).
    pub clauses_eligible: u64,
    /// Class sums [`TmModel::predict_packed_with`] never computed because
    /// the running leader was already uncatchable.
    pub classes_pruned: u64,
    /// 64-row groups evaluated by the bit-sliced engine (`tm::slice`).
    pub sliced_groups: u64,
    /// Rows those sliced groups covered (≤ 64 × `sliced_groups`; the
    /// ragged tail group counts only its live lanes).
    pub sliced_rows: u64,
}

/// A copyable snapshot of [`ForwardScratch`]'s hot-loop telemetry — the
/// form the counters travel in once they leave the scratch: backends
/// expose it ([`crate::runtime::InferenceBackend::hot_loop_stats`]), the
/// coordinator folds per-batch deltas into its pool metrics, and `serve`
/// prints the per-tenant skip rate from the aggregated copy.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HotLoopStats {
    /// Rows evaluated.
    pub rows: u64,
    /// Clauses the index skipped without evaluation.
    pub clauses_skipped: u64,
    /// Clauses an unindexed scan would have evaluated.
    pub clauses_eligible: u64,
    /// Classes the early-exit argmax never summed.
    pub classes_pruned: u64,
    /// 64-row groups the bit-sliced engine evaluated.
    pub sliced_groups: u64,
    /// Rows that took the sliced path (subset of `rows`).
    pub sliced_rows: u64,
}

impl HotLoopStats {
    /// Fraction of eligible clause evaluations the index skipped.
    pub fn skip_rate(&self) -> f64 {
        if self.clauses_eligible == 0 {
            0.0
        } else {
            self.clauses_skipped as f64 / self.clauses_eligible as f64
        }
    }

    /// Counter-wise difference against an earlier snapshot of the same
    /// scratch (saturating, so a mid-flight `reset` yields zeros rather
    /// than wrapping) — how the coordinator turns cumulative backend
    /// counters into additive per-batch metric deltas.
    pub fn delta_since(&self, earlier: &HotLoopStats) -> HotLoopStats {
        HotLoopStats {
            rows: self.rows.saturating_sub(earlier.rows),
            clauses_skipped: self.clauses_skipped.saturating_sub(earlier.clauses_skipped),
            clauses_eligible: self.clauses_eligible.saturating_sub(earlier.clauses_eligible),
            classes_pruned: self.classes_pruned.saturating_sub(earlier.classes_pruned),
            sliced_groups: self.sliced_groups.saturating_sub(earlier.sliced_groups),
            sliced_rows: self.sliced_rows.saturating_sub(earlier.sliced_rows),
        }
    }
}

impl ForwardScratch {
    pub fn new() -> ForwardScratch {
        ForwardScratch::default()
    }

    /// Fraction of eligible clause evaluations the index skipped.
    pub fn skip_rate(&self) -> f64 {
        if self.clauses_eligible == 0 {
            0.0
        } else {
            self.clauses_skipped as f64 / self.clauses_eligible as f64
        }
    }

    /// Copyable snapshot of the telemetry counters.
    pub fn stats(&self) -> HotLoopStats {
        HotLoopStats {
            rows: self.rows,
            clauses_skipped: self.clauses_skipped,
            clauses_eligible: self.clauses_eligible,
            classes_pruned: self.classes_pruned,
            sliced_groups: self.sliced_groups,
            sliced_rows: self.sliced_rows,
        }
    }

    /// Zero the telemetry counters (buffers keep their capacity).
    pub fn reset(&mut self) {
        self.rows = 0;
        self.clauses_skipped = 0;
        self.clauses_eligible = 0;
        self.classes_pruned = 0;
        self.sliced_groups = 0;
        self.sliced_rows = 0;
    }
}

/// A synthetic workload description used by the scaling sweeps (Figs.
/// 10–12), where no trained model exists: clause bits are generated from a
/// target fire-rate instead.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadSpec {
    pub n_classes: usize,
    pub clauses_per_class: usize,
    /// Number of Boolean input features (for clause-block depth).
    pub n_features: usize,
    /// Probability a clause fires on a given sample.
    pub fire_rate: f64,
}

/// Pack a bit vector into u64 words (LSB-first within each word) — thin
/// wrapper over the one packing loop in [`crate::tm::bits`].
pub(crate) fn pack_bits(bits: &[bool]) -> Vec<u64> {
    BitVec64::from_bools(bits).into_words()
}

/// Build the per-class polarity masks. A clause contributes to the mask
/// only if it is non-empty (an empty clause's fired bit is always 0
/// anyway, but keeping the masks tight makes them self-describing).
/// With `owned`, only clauses whose flag is set contribute — the
/// [`ClauseShard`] view slices its partial-sum masks this way, so a
/// shard's popcount can never count a fired bit another shard owns.
fn build_class_masks(
    n_classes: usize,
    clauses_per_class: usize,
    polarity: &[i8],
    nonempty: &[bool],
    owned: Option<&[bool]>,
) -> Vec<ClassMasks> {
    (0..n_classes)
        .map(|k| {
            let lo = k * clauses_per_class;
            let hi = lo + clauses_per_class;
            let start = lo / WORD_BITS;
            let span = if clauses_per_class == 0 { 0 } else { (hi - 1) / WORD_BITS + 1 - start };
            let mut pos = vec![0u64; span];
            let mut neg = vec![0u64; span];
            for c in lo..hi {
                if !nonempty[c] || owned.is_some_and(|o| !o[c]) {
                    continue;
                }
                let w = c / WORD_BITS - start;
                let bit = 1u64 << (c % WORD_BITS);
                if polarity[c] > 0 {
                    pos[w] |= bit;
                } else {
                    neg[w] |= bit;
                }
            }
            ClassMasks { start, pos, neg }
        })
        .collect()
}

/// Suffix maxima of the per-class sum upper bounds. A class's sum can
/// never exceed its count of positive-polarity (non-empty) clauses —
/// exactly `popcount` of its `pos` masks — so once a running argmax
/// leader meets `class_ub_suffix[k]`, no class `≥ k` can strictly beat
/// it and ties already resolve to the lower index.
fn build_class_ub_suffix(class_masks: &[ClassMasks], n_classes: usize) -> Vec<i32> {
    let mut suffix = vec![i32::MIN; n_classes + 1];
    for k in (0..n_classes).rev() {
        let ub: i32 = class_masks[k].pos.iter().map(|w| w.count_ones() as i32).sum();
        suffix[k] = ub.max(suffix[k + 1]);
    }
    suffix
}

impl TmModel {
    /// Construct from parts (computes the packed representation).
    pub fn assemble(
        name: String,
        n_classes: usize,
        n_features: usize,
        clauses_per_class: usize,
        include: Vec<Vec<bool>>,
        polarity: Vec<i8>,
        nonempty: Vec<bool>,
        accuracy: f64,
    ) -> TmModel {
        let include_words = words_for(2 * n_features);
        let mut packed_include = vec![0u64; include.len() * include_words];
        for (c, row) in include.iter().enumerate() {
            packed_include[c * include_words..(c + 1) * include_words]
                .copy_from_slice(&pack_bits(row));
        }
        let class_masks =
            build_class_masks(n_classes, clauses_per_class, &polarity, &nonempty, None);
        let clause_index = build_clause_index(&packed_include, include_words, &nonempty, None);
        let class_ub_suffix = build_class_ub_suffix(&class_masks, n_classes);
        TmModel {
            name,
            n_classes,
            n_features,
            clauses_per_class,
            include,
            polarity,
            nonempty,
            accuracy,
            packed_include,
            include_words,
            class_masks,
            clause_index,
            class_ub_suffix,
        }
    }

    /// Rebuild the clause index against an empirical literal
    /// distribution: `lit_one_prob[l]` is the probability literal `l`
    /// (layout `[x, ~x]`, so `2 * n_features` entries) reads 1 on a
    /// sample. Each clause re-picks the *rarest* of its included
    /// literals as its index literal, which maximizes the expected
    /// bucket skip rate; results stay bit-exact (the index only decides
    /// what gets *scanned*, never what fires). Without this call the
    /// construction-time index uses a uniform prior (lowest included
    /// literal).
    pub fn reindex_with_stats(&mut self, lit_one_prob: &[f64]) -> Result<()> {
        ensure!(
            lit_one_prob.len() == 2 * self.n_features,
            "literal stats length {} != {} literals (2 × {} features)",
            lit_one_prob.len(),
            2 * self.n_features,
            self.n_features
        );
        self.clause_index = build_clause_index(
            &self.packed_include,
            self.include_words,
            &self.nonempty,
            Some(lit_one_prob),
        );
        Ok(())
    }

    /// Shape of the clause index (skippable / always-scanned / bucket
    /// counts) — telemetry for benches and the skip-rate gate in CI.
    pub fn index_stats(&self) -> ClauseIndexStats {
        ClauseIndexStats {
            indexed: self.clause_index.n_skippable,
            fallback: self.clause_index.n_fallback,
            buckets: self.clause_index.buckets.len(),
        }
    }

    /// [`TmModel::assemble`] with `nonempty` derived from the include
    /// masks — the invariant trained artifacts satisfy; synthetic model
    /// builders should use this instead of deriving it by hand.
    pub fn assemble_derived(
        name: String,
        n_classes: usize,
        n_features: usize,
        clauses_per_class: usize,
        include: Vec<Vec<bool>>,
        polarity: Vec<i8>,
        accuracy: f64,
    ) -> TmModel {
        let nonempty = include.iter().map(|row| row.iter().any(|&b| b)).collect();
        TmModel::assemble(
            name,
            n_classes,
            n_features,
            clauses_per_class,
            include,
            polarity,
            nonempty,
            accuracy,
        )
    }

    /// Deterministic random model for synthetic workloads (benches and
    /// the artifact-free coordinator tests): include masks drawn at
    /// `density`, alternating clause polarity.
    pub fn synthetic(
        name: &str,
        n_classes: usize,
        clauses_per_class: usize,
        n_features: usize,
        density: f64,
        seed: u64,
    ) -> TmModel {
        let mut rng = crate::util::SplitMix64::new(seed);
        let c_total = n_classes * clauses_per_class;
        let include: Vec<Vec<bool>> = (0..c_total)
            .map(|_| (0..2 * n_features).map(|_| rng.next_bool(density)).collect())
            .collect();
        let polarity: Vec<i8> = (0..c_total).map(|c| if c % 2 == 0 { 1 } else { -1 }).collect();
        TmModel::assemble_derived(
            name.to_string(),
            n_classes,
            n_features,
            clauses_per_class,
            include,
            polarity,
            0.0,
        )
    }

    /// Serialize to the artifact-JSON interchange layout —
    /// [`TmModel::load`]'s exact inverse (include masks as `"0101…"`
    /// bitstrings, `nonempty` as 0/1). This is how tests and the
    /// multi-model smoke driver materialize (and *re*-materialize, for
    /// hot-swap) model artifacts on disk without the Python build path.
    pub fn to_json(&self) -> String {
        fn bitstring(bits: &[bool]) -> String {
            bits.iter().map(|&b| if b { '1' } else { '0' }).collect()
        }
        let include: Vec<String> =
            self.include.iter().map(|row| format!("\"{}\"", bitstring(row))).collect();
        let polarity: Vec<String> = self.polarity.iter().map(|p| p.to_string()).collect();
        let nonempty: Vec<String> =
            self.nonempty.iter().map(|&b| if b { "1" } else { "0" }.to_string()).collect();
        format!(
            "{{\n  \"name\": \"{}\",\n  \"n_classes\": {},\n  \"n_features\": {},\n  \
             \"clauses_per_class\": {},\n  \"accuracy\": {},\n  \"include\": [{}],\n  \
             \"polarity\": [{}],\n  \"nonempty\": [{}]\n}}\n",
            self.name,
            self.n_classes,
            self.n_features,
            self.clauses_per_class,
            self.accuracy,
            include.join(", "),
            polarity.join(", "),
            nonempty.join(", ")
        )
    }

    pub fn load(path: &Path) -> Result<TmModel> {
        let doc = json::parse_file(path)?;
        let n_classes = doc.get("n_classes")?.as_usize()?;
        let n_features = doc.get("n_features")?.as_usize()?;
        let clauses_per_class = doc.get("clauses_per_class")?.as_usize()?;
        let include = doc
            .get("include")?
            .as_arr()?
            .iter()
            .map(|row| parse_bits(row.as_str()?))
            .collect::<Result<Vec<_>>>()?;
        let polarity = doc
            .get("polarity")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_i64()? as i8))
            .collect::<Result<Vec<_>>>()?;
        let nonempty = doc
            .get("nonempty")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_i64()? != 0))
            .collect::<Result<Vec<_>>>()?;
        let c_total = n_classes * clauses_per_class;
        ensure!(
            include.len() == c_total,
            "corrupt model artifact {}: {} include rows != {c_total} clauses \
             ({n_classes} classes × {clauses_per_class} clauses/class)",
            path.display(),
            include.len()
        );
        ensure!(
            polarity.len() == c_total,
            "corrupt model artifact {}: {} polarity entries != {c_total} clauses",
            path.display(),
            polarity.len()
        );
        ensure!(
            nonempty.len() == c_total,
            "corrupt model artifact {}: {} nonempty flags != {c_total} clauses",
            path.display(),
            nonempty.len()
        );
        for (c, row) in include.iter().enumerate() {
            ensure!(
                row.len() == 2 * n_features,
                "corrupt model artifact {}: clause {c} has {} literals, expected {} \
                 (2 × {n_features} features)",
                path.display(),
                row.len(),
                2 * n_features
            );
        }
        let name = doc
            .get_opt("name")
            .and_then(|v| v.as_str().ok().map(String::from))
            .unwrap_or_else(|| "unnamed".into());
        let accuracy = doc.get_opt("accuracy").and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
        Ok(TmModel::assemble(
            name,
            n_classes,
            n_features,
            clauses_per_class,
            include,
            polarity,
            nonempty,
            accuracy,
        ))
    }

    pub fn c_total(&self) -> usize {
        self.n_classes * self.clauses_per_class
    }

    /// Literal vector `[x, ~x]` for one Boolean input sample.
    pub fn literals(&self, x_bool: &[bool]) -> Vec<bool> {
        debug_assert_eq!(x_bool.len(), self.n_features);
        let mut lits = Vec::with_capacity(2 * self.n_features);
        lits.extend_from_slice(x_bool);
        lits.extend(x_bool.iter().map(|&b| !b));
        lits
    }

    /// Packed literal vector `[x, ~x]` from packed features: the `~x`
    /// half is built word-wise (negate + tail-mask + bit-shift into
    /// place), so no per-bit loop runs at any feature width.
    pub fn packed_literals(&self, x_words: &[u64]) -> BitVec64 {
        let mut out = vec![0u64; words_for(2 * self.n_features)];
        let mut negated = Vec::with_capacity(x_words.len());
        self.packed_literals_into(x_words, &mut negated, &mut out);
        BitVec64::from_words(2 * self.n_features, out)
    }

    /// Allocation-free core of [`TmModel::packed_literals`]: writes the
    /// literal words into `out` (length `words_for(2 * n_features)`,
    /// overwritten) using `negated` as reusable scratch — the batched
    /// forward pass hoists both buffers out of its row loop (public so
    /// the hotpath bench can reproduce the production loop shape).
    pub fn packed_literals_into(&self, x_words: &[u64], negated: &mut Vec<u64>, out: &mut [u64]) {
        let f = self.n_features;
        debug_assert_eq!(x_words.len(), words_for(f));
        debug_assert_eq!(out.len(), words_for(2 * f));
        out.fill(0);
        copy_bits(out, 0, x_words, f);
        // ~x, masked to the feature width so no stray tail bits leak in.
        negated.clear();
        negated.extend(x_words.iter().map(|w| !w));
        if let Some(last) = negated.last_mut() {
            *last &= tail_mask(f);
        }
        copy_bits(out, f, negated, f);
    }

    /// Evaluate one clause on a pre-packed literal vector (pack once with
    /// [`TmModel::packed_literals`], reuse across every clause).
    #[inline]
    pub fn clause_fires(&self, clause: usize, lits: &BitVec64) -> bool {
        self.clause_fires_packed(clause, lits.words())
    }

    /// Packed include-mask words of one clause (a row of the flat arena).
    #[inline]
    fn include_row(&self, clause: usize) -> &[u64] {
        &self.packed_include[clause * self.include_words..(clause + 1) * self.include_words]
    }

    /// Word-wise clause evaluation: fires iff the clause is non-empty and
    /// every included literal is 1, i.e. `include & !literals == 0` in
    /// every word — evaluated through the chunked 4×`u64`-lane
    /// [`super::bits::is_subset`]. This is the single `nonempty`
    /// checkpoint on the evaluation path.
    #[inline]
    pub fn clause_fires_packed(&self, clause: usize, lit_words: &[u64]) -> bool {
        self.nonempty[clause] && is_subset(self.include_row(clause), lit_words)
    }

    /// Word-serial scalar clause evaluation — the hot loop this crate
    /// shipped before the chunked/indexed rework, kept public as the
    /// differential baseline for `benches/hotpath_forward.rs` and the
    /// property suites. Semantically identical to
    /// [`TmModel::clause_fires_packed`].
    #[inline]
    pub fn clause_fires_scalar(&self, clause: usize, lit_words: &[u64]) -> bool {
        if !self.nonempty[clause] {
            return false;
        }
        self.include_row(clause)
            .iter()
            .zip(lit_words)
            .all(|(&inc, &lit)| inc & !lit == 0)
    }

    /// Fired-clause words for one pre-packed literal vector: one bit per
    /// clause, class-major, `words_for(c_total)` words. `out` is
    /// overwritten. Unindexed full scan; fired bits accumulate in a
    /// local word flushed once per 64 clauses, so the output slice is
    /// stored to once per word instead of once per fired clause.
    pub fn fired_words_into(&self, lit_words: &[u64], out: &mut [u64]) {
        debug_assert_eq!(out.len(), words_for(self.c_total()));
        let c_total = self.c_total();
        let mut word = 0u64;
        let mut w = 0usize;
        for c in 0..c_total {
            if self.clause_fires_packed(c, lit_words) {
                word |= 1u64 << (c % WORD_BITS);
            }
            if c % WORD_BITS == WORD_BITS - 1 {
                out[w] = word;
                word = 0;
                w += 1;
            }
        }
        if c_total % WORD_BITS != 0 {
            out[w] = word;
        }
    }

    /// [`TmModel::fired_words_into`] with the pre-rework scalar clause
    /// loop and bit-at-a-time stores — the seed `forward_packed` inner
    /// shape, kept as the timing baseline (`benches/hotpath_forward.rs`).
    pub fn fired_words_into_scalar(&self, lit_words: &[u64], out: &mut [u64]) {
        debug_assert_eq!(out.len(), words_for(self.c_total()));
        out.fill(0);
        for c in 0..self.c_total() {
            if self.clause_fires_scalar(c, lit_words) {
                out[c / WORD_BITS] |= 1u64 << (c % WORD_BITS);
            }
        }
    }

    /// One scan slot of the clause index: evaluate its (arena-local)
    /// include row and set the original clause's fired bit.
    #[inline]
    fn scan_slot(&self, slot: usize, lit_words: &[u64], out: &mut [u64]) {
        let idx = &self.clause_index;
        let inc = &idx.arena[slot * idx.stride..(slot + 1) * idx.stride];
        if is_subset(inc, lit_words) {
            let c = idx.clause_of[slot] as usize;
            out[c / WORD_BITS] |= 1u64 << (c % WORD_BITS);
        }
    }

    /// Clause-indexed fired-word computation — the production hot path.
    /// Scans the fallback bucket, then only the buckets whose index
    /// literal the sample sets; every other bucket is skipped whole (its
    /// clauses cannot fire — their index literal reads 0). Bit-exact
    /// with [`TmModel::fired_words_into`]; returns the number of clauses
    /// skipped without evaluation (the skip-rate numerator).
    pub fn fired_words_into_indexed(&self, lit_words: &[u64], out: &mut [u64]) -> usize {
        debug_assert_eq!(out.len(), words_for(self.c_total()));
        out.fill(0);
        let idx = &self.clause_index;
        for slot in 0..idx.n_fallback {
            self.scan_slot(slot, lit_words, out);
        }
        let mut skipped = 0usize;
        for b in &idx.buckets {
            let lit = b.lit as usize;
            if (lit_words[lit / WORD_BITS] >> (lit % WORD_BITS)) & 1 == 1 {
                for slot in b.start as usize..b.end as usize {
                    self.scan_slot(slot, lit_words, out);
                }
            } else {
                skipped += (b.end - b.start) as usize;
            }
        }
        skipped
    }

    /// Class sums from packed fired-clause words into caller scratch:
    /// `popcount(fired & pos) − popcount(fired & neg)` per class — the
    /// software analogue of the paper's time-domain popcount voter. Each
    /// class's mask slices are resolved once, outside its word loop;
    /// `out` (length `n_classes`) is overwritten.
    pub fn class_sums_into(&self, fired_words: &[u64], out: &mut [i32]) {
        debug_assert_eq!(fired_words.len(), words_for(self.c_total()));
        debug_assert_eq!(out.len(), self.n_classes);
        for (k, m) in self.class_masks.iter().enumerate() {
            let mut s = 0i32;
            for (w, (&p, &n)) in m.pos.iter().zip(&m.neg).enumerate() {
                let f = fired_words[m.start + w];
                s += (f & p).count_ones() as i32 - (f & n).count_ones() as i32;
            }
            out[k] = s;
        }
    }

    /// Allocating convenience over [`TmModel::class_sums_into`].
    pub fn class_sums_from_fired(&self, fired_words: &[u64]) -> Vec<i32> {
        let mut out = vec![0i32; self.n_classes];
        self.class_sums_into(fired_words, &mut out);
        out
    }

    /// One class's signed sum (the early-exit argmax computes classes
    /// lazily, so this exists separately from the batch form).
    #[inline]
    fn class_sum_one(&self, k: usize, fired_words: &[u64]) -> i32 {
        let m = &self.class_masks[k];
        let mut s = 0i32;
        for (w, (&p, &n)) in m.pos.iter().zip(&m.neg).enumerate() {
            let f = fired_words[m.start + w];
            s += (f & p).count_ones() as i32 - (f & n).count_ones() as i32;
        }
        s
    }

    /// Per-clause signed summation over packed fired words — the pre-
    /// packed-data-path voter, kept (not on the request path) as the
    /// differential baseline for `benches/packed_popcount.rs` and the
    /// property suites.
    pub fn class_sums_per_clause(&self, fired_words: &[u64]) -> Vec<i32> {
        let mut sums = vec![0i32; self.n_classes];
        for c in 0..self.c_total() {
            if (fired_words[c / WORD_BITS] >> (c % WORD_BITS)) & 1 == 1 {
                sums[c / self.clauses_per_class] += self.polarity[c] as i32;
            }
        }
        sums
    }

    /// Batched packed forward pass — the request path. Consumes packed
    /// feature rows, emits packed fired words per sample, class sums via
    /// the polarity-mask popcount, and argmax predictions (ties → lowest
    /// index, matching `jnp.argmax`). Allocates a fresh scratch; callers
    /// on the serving path hold a [`ForwardScratch`] and use
    /// [`TmModel::forward_packed_with`] instead.
    pub fn forward_packed(&self, batch: &PackedBatch) -> Result<ForwardOutput> {
        self.forward_packed_with(batch, &mut ForwardScratch::new())
    }

    /// [`TmModel::forward_packed`] with caller-held scratch — the
    /// adaptive dispatch seam. Small batches run the row-major
    /// clause-indexed loop ([`TmModel::forward_indexed_with`]); batches
    /// of at least [`super::slice::SLICED_MIN_ROWS`] rows take the
    /// bit-sliced transposed engine ([`TmModel::forward_sliced_with`]),
    /// which evaluates each clause against 64 rows per word op. The two
    /// engines are bit-exact (sums, predictions, fired words, tie
    /// resolution — the sliced property suite pins this), so callers
    /// never observe which one ran except through the
    /// `sliced_groups`/`sliced_rows` telemetry on `scratch`.
    pub fn forward_packed_with(
        &self,
        batch: &PackedBatch,
        scratch: &mut ForwardScratch,
    ) -> Result<ForwardOutput> {
        if batch.rows() >= super::slice::SLICED_MIN_ROWS {
            self.forward_sliced_with(batch, scratch)
        } else {
            self.forward_indexed_with(batch, scratch)
        }
    }

    /// The row-major clause-indexed forward engine: the per-sample body
    /// allocates nothing, literal/fired/sums buffers are reused across
    /// batches, and clause evaluation runs through the clause-indexed
    /// scan of [`TmModel::fired_words_into_indexed`] (bit-exact with the
    /// full scan — the index only decides what gets *scanned*). Skip
    /// telemetry accumulates on `scratch`. Public so benches and the
    /// property suites can pin it against the sliced engine directly;
    /// production callers go through the dispatching
    /// [`TmModel::forward_packed_with`].
    pub fn forward_indexed_with(
        &self,
        batch: &PackedBatch,
        scratch: &mut ForwardScratch,
    ) -> Result<ForwardOutput> {
        ensure!(
            batch.is_empty() || batch.bits() == self.n_features,
            "batch feature width {} != model features {}",
            batch.bits(),
            self.n_features
        );
        let k = self.n_classes;
        let c_total = self.c_total();
        let mut out = ForwardOutput::empty(k, c_total);
        out.batch = batch.rows();
        out.sums.reserve(batch.rows() * k);
        out.pred.reserve(batch.rows());
        scratch.lits.resize(words_for(2 * self.n_features), 0);
        scratch.fired.resize(words_for(c_total), 0);
        scratch.sums.resize(k, 0);
        for r in 0..batch.rows() {
            // Field-by-field borrows keep the scratch buffers disjoint.
            let ForwardScratch { lits, negated, fired, sums, .. } = scratch;
            self.packed_literals_into(batch.row(r), negated, lits);
            let skipped = self.fired_words_into_indexed(lits, fired);
            self.class_sums_into(fired, sums);
            let mut best = 0usize;
            for (ki, &s) in sums.iter().enumerate() {
                // Ties resolve to the lowest class index (jnp.argmax).
                if s > sums[best] {
                    best = ki;
                }
            }
            out.fired.push_words(fired);
            out.sums.extend_from_slice(sums);
            out.pred.push(best as i32);
            scratch.rows += 1;
            scratch.clauses_skipped += skipped as u64;
            scratch.clauses_eligible += c_total as u64;
        }
        Ok(out)
    }

    /// Argmax-only batched forward pass with the exact class-sum early
    /// exit: classes are scanned in index order and the scan stops as
    /// soon as the running leader meets `class_ub_suffix[k]` — no
    /// remaining class can strictly beat it, and a tie would resolve to
    /// the (lower) leader index anyway, so predictions are identical to
    /// [`TmModel::forward_packed`]'s. For callers that consume only
    /// `pred` (no sums, no fired bits).
    pub fn predict_packed(&self, batch: &PackedBatch) -> Result<Vec<i32>> {
        self.predict_packed_with(batch, &mut ForwardScratch::new())
    }

    /// [`TmModel::predict_packed`] with caller-held scratch; pruned-class
    /// telemetry accumulates in `scratch.classes_pruned`.
    pub fn predict_packed_with(
        &self,
        batch: &PackedBatch,
        scratch: &mut ForwardScratch,
    ) -> Result<Vec<i32>> {
        ensure!(
            batch.is_empty() || batch.bits() == self.n_features,
            "batch feature width {} != model features {}",
            batch.bits(),
            self.n_features
        );
        let c_total = self.c_total();
        scratch.lits.resize(words_for(2 * self.n_features), 0);
        scratch.fired.resize(words_for(c_total), 0);
        let mut pred = Vec::with_capacity(batch.rows());
        for r in 0..batch.rows() {
            let ForwardScratch { lits, negated, fired, .. } = scratch;
            self.packed_literals_into(batch.row(r), negated, lits);
            let skipped = self.fired_words_into_indexed(lits, fired);
            let mut pruned = 0u64;
            if self.n_classes == 0 {
                pred.push(0);
            } else {
                let mut best = 0usize;
                let mut best_sum = self.class_sum_one(0, fired);
                let mut k = 1;
                while k < self.n_classes {
                    if best_sum >= self.class_ub_suffix[k] {
                        pruned = (self.n_classes - k) as u64;
                        break;
                    }
                    let s = self.class_sum_one(k, fired);
                    if s > best_sum {
                        best = k;
                        best_sum = s;
                    }
                    k += 1;
                }
                pred.push(best as i32);
            }
            scratch.classes_pruned += pruned;
            scratch.rows += 1;
            scratch.clauses_skipped += skipped as u64;
            scratch.clauses_eligible += c_total as u64;
        }
        Ok(pred)
    }

    /// Clause outputs for one sample, grouped per class — the PDL select
    /// inputs of the hardware. Packs the literal vector once and evaluates
    /// all clauses word-wise (§Perf L3).
    pub fn clause_bits(&self, x_bool: &[bool]) -> Vec<Vec<bool>> {
        let lits = self.packed_literals(BitVec64::from_bools(x_bool).words());
        (0..self.n_classes)
            .map(|k| {
                let lo = k * self.clauses_per_class;
                (lo..lo + self.clauses_per_class)
                    .map(|c| self.clause_fires_packed(c, lits.words()))
                    .collect()
            })
            .collect()
    }

    /// Signed class sums for one sample (single-row convenience over the
    /// packed path).
    pub fn class_sums(&self, x_bool: &[bool]) -> Vec<i32> {
        let lits = self.packed_literals(BitVec64::from_bools(x_bool).words());
        let mut fired = vec![0u64; words_for(self.c_total())];
        self.fired_words_into(lits.words(), &mut fired);
        self.class_sums_from_fired(&fired)
    }

    /// Functional argmax prediction (ties resolve to the lowest index,
    /// matching `jnp.argmax`).
    pub fn predict(&self, x_bool: &[bool]) -> usize {
        let sums = self.class_sums(x_bool);
        let mut best = 0usize;
        for (k, &s) in sums.iter().enumerate() {
            if s > sums[best] {
                best = k;
            }
        }
        best
    }

    /// The maximum clause fan-in (number of includes) — determines the
    /// clause block's LUT-tree depth for the bundled-data delay.
    pub fn max_clause_fanin(&self) -> usize {
        self.include
            .iter()
            .map(|row| row.iter().filter(|&&b| b).count())
            .max()
            .unwrap_or(0)
    }

    /// Naive reference forward pass for one sample — bool-wise loops, no
    /// bit packing. The clause-evaluation *loop* is deliberately
    /// independent of the packed hot path so differential tests
    /// (`tests/native_backend.rs`) can pit the `NativeBackend` against it
    /// on randomized models; the stored `nonempty` mask is consulted like
    /// the production path does (it is authoritative, not re-derived).
    ///
    /// Returns `(fired, sums, pred)`: flat clause bits (class-major),
    /// signed class sums, and the argmax prediction (ties → lowest index).
    pub fn forward_reference(&self, x_bool: &[bool]) -> (Vec<bool>, Vec<i32>, usize) {
        assert_eq!(x_bool.len(), self.n_features, "feature width mismatch");
        let lits = self.literals(x_bool);
        let mut fired = Vec::with_capacity(self.c_total());
        for clause in 0..self.c_total() {
            let mut all = true;
            for (&lit, &inc) in lits.iter().zip(&self.include[clause]) {
                if inc && !lit {
                    all = false;
                }
            }
            fired.push(self.nonempty[clause] && all);
        }
        let mut sums = vec![0i32; self.n_classes];
        for (clause, &f) in fired.iter().enumerate() {
            if f {
                sums[clause / self.clauses_per_class] += self.polarity[clause] as i32;
            }
        }
        let mut pred = 0usize;
        for (k, &s) in sums.iter().enumerate() {
            if s > sums[pred] {
                pred = k;
            }
        }
        (fired, sums, pred)
    }

    /// Workload view of this model (for the shared hardware builders).
    pub fn workload(&self) -> WorkloadSpec {
        WorkloadSpec {
            n_classes: self.n_classes,
            clauses_per_class: self.clauses_per_class,
            n_features: self.n_features,
            fire_rate: 0.5,
        }
    }
}

/// One clause shard of a model — the unit of the scatter/reduce plan
/// (ROADMAP item 3; Abeyrathna et al., arXiv 2009.04861: clause
/// evaluation is embarrassingly parallel once partial votes merge).
///
/// A shard is a *view*: a contiguous slice `[slot_lo, slot_hi)` of the
/// clause index's scan slots (the permuted, cache-contiguous arena
/// order of the PR-7 hot loop — fallback-first, then bucket-major), the
/// fallback range and skip buckets clipped to that slice, per-class
/// polarity masks sliced to the clauses the slice owns, and the
/// shard-local `class_ub_suffix` bounds. Shards of one plan partition
/// the scan slots exactly, so:
///
/// * partial class sums add across shards to the unsharded
///   [`TmModel::class_sums_into`] result (each clause votes in exactly
///   one shard),
/// * shard-local fired rows OR to the unsharded fired rows (bit sets
///   are disjoint), and
/// * bucket skipping still works *within* a shard — a clipped bucket
///   whose index literal reads 0 is skipped whole, so the near-constant
///   scaling in clause count composes with the skip index.
///
/// Dead clauses (`nonempty` false) have no scan slot and belong to no
/// shard; their fired bits stay 0 everywhere, as in the unsharded path.
/// Shards may be empty when `n_shards` exceeds the live clause count —
/// an empty shard contributes all-zero partials.
#[derive(Debug, Clone)]
pub struct ClauseShard {
    model: Arc<TmModel>,
    index: usize,
    n_shards: usize,
    /// Scan-slot range of this shard (contiguous in the index arena).
    pub(crate) slot_lo: usize,
    pub(crate) slot_hi: usize,
    /// Fallback slots ∩ the shard's slice — scanned on every sample.
    pub(crate) fallback_lo: usize,
    pub(crate) fallback_hi: usize,
    /// Skip buckets clipped to the slice (a bucket straddling a shard
    /// boundary is evaluated partly by each neighbor).
    pub(crate) buckets: Vec<IndexBucket>,
    /// Per-class polarity masks over shard-owned clauses only.
    class_masks: Vec<ClassMasks>,
    /// `class_ub[k]` = this shard's positive-polarity clause count for
    /// class `k`: the most the shard can add to class `k`'s sum. Across
    /// shards these add to the model-level bound.
    class_ub: Vec<i32>,
    /// Suffix maxima of `class_ub` with the `i32::MIN` sentinel at
    /// `n_classes` — the shard-local analogue of the model's early-exit
    /// bound: once a reduce's running leader meets
    /// `Σ_remaining-shards class_ub_suffix[k]`, no later class can win.
    class_ub_suffix: Vec<i32>,
}

impl ClauseShard {
    /// Carve shard `index` of `n_shards` out of a model. Slot ranges are
    /// the balanced contiguous partition `[i·n/s, (i+1)·n/s)`, so shard
    /// sizes differ by at most one slot.
    pub fn new(model: Arc<TmModel>, index: usize, n_shards: usize) -> Result<ClauseShard> {
        ensure!(n_shards >= 1, "shard plan needs at least one shard");
        ensure!(index < n_shards, "shard index {index} out of range for {n_shards} shards");
        let n_slots = model.clause_index.clause_of.len();
        let slot_lo = index * n_slots / n_shards;
        let slot_hi = (index + 1) * n_slots / n_shards;
        let mut owned = vec![false; model.c_total()];
        for slot in slot_lo..slot_hi {
            owned[model.clause_index.clause_of[slot] as usize] = true;
        }
        let class_masks = build_class_masks(
            model.n_classes,
            model.clauses_per_class,
            &model.polarity,
            &model.nonempty,
            Some(&owned),
        );
        let class_ub: Vec<i32> = class_masks
            .iter()
            .map(|m| m.pos.iter().map(|w| w.count_ones() as i32).sum())
            .collect();
        let class_ub_suffix = build_class_ub_suffix(&class_masks, model.n_classes);
        let idx = &model.clause_index;
        let fallback_lo = slot_lo.min(idx.n_fallback);
        let fallback_hi = slot_hi.min(idx.n_fallback);
        let buckets = idx
            .buckets
            .iter()
            .filter_map(|b| {
                let lo = (b.start as usize).max(slot_lo);
                let hi = (b.end as usize).min(slot_hi);
                (lo < hi).then(|| IndexBucket { lit: b.lit, start: lo as u32, end: hi as u32 })
            })
            .collect();
        Ok(ClauseShard {
            model,
            index,
            n_shards,
            slot_lo,
            slot_hi,
            fallback_lo,
            fallback_hi,
            buckets,
            class_masks,
            class_ub,
            class_ub_suffix,
        })
    }

    /// All `n_shards` shards of a model — the full scatter plan.
    pub fn split(model: &Arc<TmModel>, n_shards: usize) -> Result<Vec<ClauseShard>> {
        (0..n_shards).map(|i| ClauseShard::new(Arc::clone(model), i, n_shards)).collect()
    }

    /// Re-stamp this shard's plan coordinates without re-partitioning its
    /// scan slots. This is the subset-model path of the v2 artifact
    /// store: a worker loads only its own clause range from disk (the
    /// other clauses come back dead — `nonempty = false`, so they can
    /// never fire), builds a whole-model shard over it
    /// (`ClauseShard::new(subset, 0, 1)`), and then claims its true
    /// position in the scatter plan so [`merge_partials`] sees the exact
    /// cover `(0, n) … (n-1, n)`. Correct because partials carry
    /// full-width `c_total` rows and class sums only count live clauses:
    /// a disjoint live-clause partition across workers merges
    /// bit-identically with the unsharded forward pass regardless of
    /// which slots each worker *scanned*.
    pub fn with_plan_coords(mut self, index: usize, n_shards: usize) -> Result<ClauseShard> {
        ensure!(n_shards >= 1, "shard plan needs at least one shard");
        ensure!(index < n_shards, "shard index {index} out of range for {n_shards} shards");
        self.index = index;
        self.n_shards = n_shards;
        Ok(self)
    }

    pub fn model(&self) -> &Arc<TmModel> {
        &self.model
    }

    pub fn index(&self) -> usize {
        self.index
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Live scan slots this shard evaluates (0 for an empty shard).
    pub fn n_slots(&self) -> usize {
        self.slot_hi - self.slot_lo
    }

    /// Per-class positive-vote upper bounds within this shard.
    pub fn class_ub(&self) -> &[i32] {
        &self.class_ub
    }

    /// Shard-local suffix maxima of [`ClauseShard::class_ub`] (sentinel
    /// `i32::MIN` at `n_classes`).
    pub fn class_ub_suffix(&self) -> &[i32] {
        &self.class_ub_suffix
    }

    /// Batched partial forward — the shard half of scatter/reduce, with
    /// the same adaptive dispatch as [`TmModel::forward_packed_with`]:
    /// batches of at least [`super::slice::SLICED_MIN_ROWS`] rows run
    /// the bit-sliced engine over this shard's slot slice
    /// ([`ClauseShard::partial_sliced_into`]); smaller batches keep the
    /// row-major loop. Both emit identical partials, so the reduce never
    /// observes which engine a shard ran.
    pub fn partial_class_sums_into(
        &self,
        batch: &PackedBatch,
        scratch: &mut ForwardScratch,
        out: &mut PartialOutput,
    ) -> Result<()> {
        if batch.rows() >= super::slice::SLICED_MIN_ROWS {
            self.partial_sliced_into(batch, scratch, out)
        } else {
            self.partial_indexed_into(batch, scratch, out)
        }
    }

    /// The row-major partial engine. Evaluates only this shard's scan
    /// slots (fallback slice unconditionally, clipped buckets behind
    /// their index literal, so skip telemetry keeps accumulating on
    /// `scratch`) and emits partial class sums through the sliced
    /// polarity masks plus shard-local fired rows into `out` (reset
    /// first; buffers keep their capacity). `scratch.clauses_eligible`
    /// counts this shard's slots only — the shard's share of the
    /// unindexed work.
    pub fn partial_indexed_into(
        &self,
        batch: &PackedBatch,
        scratch: &mut ForwardScratch,
        out: &mut PartialOutput,
    ) -> Result<()> {
        let m = &*self.model;
        ensure!(
            batch.is_empty() || batch.bits() == m.n_features,
            "batch feature width {} != model features {}",
            batch.bits(),
            m.n_features
        );
        let k = m.n_classes;
        let c_total = m.c_total();
        out.batch = batch.rows();
        out.n_classes = k;
        out.c_total = c_total;
        out.shard = self.index;
        out.n_shards = self.n_shards;
        out.sums.clear();
        out.sums.reserve(batch.rows() * k);
        if out.fired.bits() == c_total {
            out.fired.truncate_rows(0);
        } else {
            out.fired = PackedBatch::new(c_total);
        }
        scratch.lits.resize(words_for(2 * m.n_features), 0);
        scratch.fired.resize(words_for(c_total), 0);
        scratch.sums.resize(k, 0);
        for r in 0..batch.rows() {
            let ForwardScratch { lits, negated, fired, sums, .. } = scratch;
            m.packed_literals_into(batch.row(r), negated, lits);
            fired.fill(0);
            for slot in self.fallback_lo..self.fallback_hi {
                m.scan_slot(slot, lits, fired);
            }
            let mut skipped = 0usize;
            for b in &self.buckets {
                let lit = b.lit as usize;
                if (lits[lit / WORD_BITS] >> (lit % WORD_BITS)) & 1 == 1 {
                    for slot in b.start as usize..b.end as usize {
                        m.scan_slot(slot, lits, fired);
                    }
                } else {
                    skipped += (b.end - b.start) as usize;
                }
            }
            for (ki, cm) in self.class_masks.iter().enumerate() {
                let mut s = 0i32;
                for (w, (&p, &n)) in cm.pos.iter().zip(&cm.neg).enumerate() {
                    let fw = fired[cm.start + w];
                    s += (fw & p).count_ones() as i32 - (fw & n).count_ones() as i32;
                }
                sums[ki] = s;
            }
            out.fired.push_words(fired);
            out.sums.extend_from_slice(sums);
            scratch.rows += 1;
            scratch.clauses_skipped += skipped as u64;
            scratch.clauses_eligible += (self.slot_hi - self.slot_lo) as u64;
        }
        Ok(())
    }

    /// Allocating convenience over [`ClauseShard::partial_class_sums_into`].
    pub fn partial(&self, batch: &PackedBatch) -> Result<PartialOutput> {
        let mut out =
            PartialOutput::empty(self.model.n_classes, self.model.c_total(), self.index, self.n_shards);
        self.partial_class_sums_into(batch, &mut ForwardScratch::new(), &mut out)?;
        Ok(out)
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// A tiny hand-built model: 2 classes × 2 clauses over 2 features.
    /// Class 0: clause0 (+) includes x0; clause1 (−) includes x1.
    /// Class 1: clause0 (+) includes ~x0; clause1 (−) empty.
    pub(crate) fn toy() -> TmModel {
        TmModel::assemble(
            "toy".into(),
            2,
            2,
            2,
            vec![
                vec![true, false, false, false],  // x0
                vec![false, true, false, false],  // x1
                vec![false, false, true, false],  // ~x0
                vec![false, false, false, false], // empty
            ],
            vec![1, -1, 1, -1],
            vec![true, true, true, false],
            100.0,
        )
    }

    #[test]
    fn literals_layout() {
        let m = toy();
        assert_eq!(m.literals(&[true, false]), vec![true, false, false, true]);
    }

    #[test]
    fn packed_literals_match_bool_literals() {
        // Word-boundary feature counts: the ~x half lands at offsets
        // 63/64/65 and must shift across words correctly.
        for f in [1usize, 2, 31, 32, 33, 63, 64, 65, 100] {
            let mut rng = crate::util::SplitMix64::new(f as u64);
            let m = TmModel::synthetic("lit", 2, 3, f, 0.2, 9);
            let x: Vec<bool> = (0..f).map(|_| rng.next_bool(0.5)).collect();
            let packed = m.packed_literals(BitVec64::from_bools(&x).words());
            assert_eq!(packed.to_bools(), m.literals(&x), "f={f}");
        }
    }

    #[test]
    fn clause_semantics() {
        let m = toy();
        let lits = m.packed_literals(BitVec64::from_bools(&[true, true]).words());
        assert!(m.clause_fires(0, &lits)); // x0=1
        assert!(m.clause_fires(1, &lits)); // x1=1
        assert!(!m.clause_fires(2, &lits)); // ~x0=0
        assert!(!m.clause_fires(3, &lits)); // empty never fires
    }

    #[test]
    fn class_sums_signed() {
        let m = toy();
        // x = [1, 0]: class0 = +1 (c0 fires) − 0 = 1; class1 = 0.
        assert_eq!(m.class_sums(&[true, false]), vec![1, 0]);
        // x = [1, 1]: class0 = +1 − 1 = 0; class1 = 0.
        assert_eq!(m.class_sums(&[true, true]), vec![0, 0]);
        // x = [0, 0]: class0 = 0; class1 = +1.
        assert_eq!(m.class_sums(&[false, false]), vec![0, 1]);
    }

    #[test]
    fn popcount_sums_agree_with_per_clause_sums() {
        // The popcount voter vs the per-clause loop, on shapes whose
        // class boundaries are word-unaligned.
        for (k, cpc) in [(2usize, 2usize), (3, 21), (5, 13), (2, 32), (1, 127)] {
            let m = TmModel::synthetic("sum", k, cpc, 24, 0.2, 3);
            let mut rng = crate::util::SplitMix64::new(17);
            for _ in 0..8 {
                let x: Vec<bool> = (0..24).map(|_| rng.next_bool(0.5)).collect();
                let lits = m.packed_literals(BitVec64::from_bools(&x).words());
                let mut fired = vec![0u64; words_for(m.c_total())];
                m.fired_words_into(lits.words(), &mut fired);
                assert_eq!(
                    m.class_sums_from_fired(&fired),
                    m.class_sums_per_clause(&fired),
                    "k={k} cpc={cpc}"
                );
            }
        }
    }

    #[test]
    fn predict_argmax_lowest_tie() {
        let m = toy();
        assert_eq!(m.predict(&[true, false]), 0);
        assert_eq!(m.predict(&[false, false]), 1);
        assert_eq!(m.predict(&[true, true]), 0, "tie → lowest index (jnp.argmax)");
    }

    #[test]
    fn clause_bits_grouping() {
        let m = toy();
        let bits = m.clause_bits(&[true, false]);
        assert_eq!(bits.len(), 2);
        assert_eq!(bits[0], vec![true, false]);
        assert_eq!(bits[1], vec![false, false]);
    }

    #[test]
    fn max_fanin() {
        assert_eq!(toy().max_clause_fanin(), 1);
    }

    #[test]
    fn reference_forward_agrees_with_packed_path() {
        let m = toy();
        for x in [[true, false], [true, true], [false, false], [false, true]] {
            let (fired, sums, pred) = m.forward_reference(&x);
            assert_eq!(sums, m.class_sums(&x), "{x:?}");
            assert_eq!(pred, m.predict(&x), "{x:?}");
            let packed: Vec<bool> = m.clause_bits(&x).concat();
            assert_eq!(fired, packed, "{x:?}");
        }
    }

    #[test]
    fn forward_packed_matches_reference() {
        let m = TmModel::synthetic("fwd", 3, 10, 19, 0.25, 5);
        let mut rng = crate::util::SplitMix64::new(8);
        let rows: Vec<Vec<bool>> =
            (0..7).map(|_| (0..19).map(|_| rng.next_bool(0.5)).collect()).collect();
        let out = m.forward_packed(&PackedBatch::from_rows(&rows).unwrap()).unwrap();
        assert_eq!(out.batch, 7);
        for (i, row) in rows.iter().enumerate() {
            let (fired, sums, pred) = m.forward_reference(row);
            assert_eq!(out.sums_row(i), &sums[..], "row {i}");
            assert_eq!(out.pred[i] as usize, pred, "row {i}");
            assert_eq!(out.fired_row(i), fired, "row {i}");
        }
    }

    #[test]
    fn to_json_roundtrips_through_load() {
        let dir = std::env::temp_dir();
        for (tag, m) in [
            ("toy", toy()),
            ("synth", TmModel::synthetic("round_trip", 3, 7, 19, 0.25, 42)),
        ] {
            let path = dir.join(format!("tdpc-roundtrip-{}-{tag}.json", std::process::id()));
            std::fs::write(&path, m.to_json()).unwrap();
            let loaded = TmModel::load(&path).unwrap();
            std::fs::remove_file(&path).ok();
            assert_eq!(loaded.name, m.name, "{tag}");
            assert_eq!(loaded.n_classes, m.n_classes, "{tag}");
            assert_eq!(loaded.n_features, m.n_features, "{tag}");
            assert_eq!(loaded.clauses_per_class, m.clauses_per_class, "{tag}");
            assert_eq!(loaded.include, m.include, "{tag}");
            assert_eq!(loaded.polarity, m.polarity, "{tag}");
            assert_eq!(loaded.nonempty, m.nonempty, "{tag}");
            assert_eq!(loaded.accuracy, m.accuracy, "{tag}");
            // Behavior identical, not just fields.
            let mut rng = crate::util::SplitMix64::new(7);
            for _ in 0..16 {
                let x: Vec<bool> =
                    (0..m.n_features).map(|_| rng.next_bool(0.5)).collect();
                assert_eq!(loaded.class_sums(&x), m.class_sums(&x), "{tag}");
            }
        }
    }

    #[test]
    fn forward_packed_rejects_wrong_width() {
        let m = toy();
        let batch = PackedBatch::from_rows(&[vec![true; 3]]).unwrap();
        assert!(m.forward_packed(&batch).is_err());
        // Empty batches pass regardless of their (zero) width.
        assert_eq!(m.forward_packed(&PackedBatch::new(0)).unwrap().batch, 0);
    }

    #[test]
    fn index_shape_on_toy() {
        // Three live clauses each with one include → three one-clause
        // buckets; the dead clause (nonempty=false) gets no slot at all.
        let stats = toy().index_stats();
        assert_eq!(stats, ClauseIndexStats { indexed: 3, fallback: 0, buckets: 3 });
    }

    #[test]
    fn vacuous_nonempty_clause_lands_in_fallback_and_always_fires() {
        // The stored nonempty flag is authoritative: a flagged clause
        // with an all-false include mask fires on EVERY sample (vacuous
        // subset), so it must be scanned unconditionally — the fallback
        // bucket — and never be skipped by the index.
        let m = TmModel::assemble(
            "vacuous".into(),
            1,
            2,
            2,
            vec![vec![false; 4], vec![true, false, false, false]],
            vec![1, -1],
            vec![true, true], // clause 0 vacuous-but-live
            0.0,
        );
        let stats = m.index_stats();
        assert_eq!(stats.fallback, 1);
        assert_eq!(stats.indexed, 1);
        for x in [[false, false], [true, true], [false, true]] {
            let (fired_ref, sums_ref, _) = m.forward_reference(&x);
            assert!(fired_ref[0], "vacuous clause fires on {x:?}");
            let out = m.forward_packed(&PackedBatch::from_rows(&[x.to_vec()]).unwrap()).unwrap();
            assert_eq!(out.fired_row(0), fired_ref, "{x:?}");
            assert_eq!(out.sums_row(0), &sums_ref[..], "{x:?}");
        }
    }

    #[test]
    fn indexed_scan_matches_full_scan_and_scalar() {
        let m = TmModel::synthetic("idx", 3, 25, 40, 0.1, 11);
        let mut rng = crate::util::SplitMix64::new(23);
        let n_words = words_for(m.c_total());
        for _ in 0..32 {
            let x: Vec<bool> = (0..40).map(|_| rng.next_bool(0.5)).collect();
            let lits = m.packed_literals(BitVec64::from_bools(&x).words());
            let (mut full, mut scalar, mut indexed) =
                (vec![0u64; n_words], vec![0u64; n_words], vec![0u64; n_words]);
            m.fired_words_into(lits.words(), &mut full);
            m.fired_words_into_scalar(lits.words(), &mut scalar);
            m.fired_words_into_indexed(lits.words(), &mut indexed);
            assert_eq!(full, scalar);
            assert_eq!(full, indexed);
        }
    }

    #[test]
    fn predict_packed_agrees_with_forward_packed_including_ties() {
        // Duplicate every class's clauses so cross-class ties are common;
        // both paths must resolve them to the lowest index.
        let base = TmModel::synthetic("tie", 2, 8, 16, 0.15, 31);
        let include: Vec<Vec<bool>> =
            base.include.iter().chain(base.include.iter()).cloned().collect();
        let polarity: Vec<i8> = base.polarity.iter().chain(base.polarity.iter()).copied().collect();
        let m = TmModel::assemble_derived("tie2".into(), 4, 16, 8, include, polarity, 0.0);
        let mut rng = crate::util::SplitMix64::new(5);
        let rows: Vec<Vec<bool>> =
            (0..64).map(|_| (0..16).map(|_| rng.next_bool(0.5)).collect()).collect();
        let batch = PackedBatch::from_rows(&rows).unwrap();
        let out = m.forward_packed(&batch).unwrap();
        let mut scratch = ForwardScratch::new();
        assert_eq!(m.predict_packed_with(&batch, &mut scratch).unwrap(), out.pred);
        // The duplicated halves tie on every row, so the early exit can
        // never prune past a strictly-better later class by accident;
        // check at least one genuine tie occurred.
        assert!(
            (0..out.batch).any(|r| {
                let s = out.sums_row(r);
                s.iter().filter(|&&v| v == *s.iter().max().unwrap()).count() > 1
            }),
            "tie construction failed to produce ties"
        );
    }

    #[test]
    fn reindex_with_stats_is_bit_exact_and_validates_length() {
        let mut m = TmModel::synthetic("restat", 2, 10, 12, 0.3, 47);
        let batch = PackedBatch::from_rows(
            &(0..16)
                .map(|i| (0..12).map(|j| (i + j) % 3 == 0).collect::<Vec<bool>>())
                .collect::<Vec<_>>(),
        )
        .unwrap();
        let before = m.forward_packed(&batch).unwrap();
        assert!(m.reindex_with_stats(&[0.5; 7]).is_err(), "wrong stats length");
        // Skewed stats: literal 0 almost always set, the rest rare.
        let mut probs = vec![0.05; 24];
        probs[0] = 0.99;
        m.reindex_with_stats(&probs).unwrap();
        let after = m.forward_packed(&batch).unwrap();
        assert_eq!(before, after, "reindexing must never change results");
    }

    #[test]
    fn shard_partials_add_up_and_merge_bit_exact() {
        let m = Arc::new(TmModel::synthetic("shardy", 3, 25, 40, 0.1, 11));
        let mut rng = crate::util::SplitMix64::new(41);
        let rows: Vec<Vec<bool>> =
            (0..9).map(|_| (0..40).map(|_| rng.next_bool(0.5)).collect()).collect();
        let batch = PackedBatch::from_rows(&rows).unwrap();
        let full = m.forward_packed(&batch).unwrap();
        for n_shards in [1usize, 2, 3, 5] {
            let shards = ClauseShard::split(&m, n_shards).unwrap();
            // Shard-local positive-vote bounds partition the model bound.
            for k in 0..m.n_classes {
                let from_shards: i32 = shards.iter().map(|s| s.class_ub()[k]).sum();
                let model_ub: i32 =
                    m.class_masks[k].pos.iter().map(|w| w.count_ones() as i32).sum();
                assert_eq!(from_shards, model_ub, "n_shards={n_shards} k={k}");
            }
            let parts: Vec<PartialOutput> =
                shards.iter().map(|s| s.partial(&batch).unwrap()).collect();
            let merged = merge_partials(&parts).unwrap();
            assert_eq!(merged, full, "n_shards={n_shards}");
        }
    }

    #[test]
    fn merge_partials_rejects_bad_covers() {
        let m = Arc::new(TmModel::synthetic("cover", 2, 8, 16, 0.2, 7));
        let batch = PackedBatch::from_rows(&[vec![true; 16]]).unwrap();
        let shards = ClauseShard::split(&m, 2).unwrap();
        let parts: Vec<PartialOutput> =
            shards.iter().map(|s| s.partial(&batch).unwrap()).collect();
        assert!(merge_partials(&[]).is_err(), "empty");
        assert!(merge_partials(&parts[..1]).is_err(), "missing shard");
        assert!(
            merge_partials(&[parts[0].clone(), parts[0].clone()]).is_err(),
            "duplicate shard"
        );
        let mut other_batch = parts.clone();
        other_batch[1].batch += 1;
        assert!(merge_partials(&other_batch).is_err(), "batch mismatch");
    }

    #[test]
    fn empty_shards_contribute_nothing() {
        // toy() has 3 live scan slots; an 8-shard plan must leave some
        // shards empty, and the merge must still be exact.
        let m = Arc::new(toy());
        let batch =
            PackedBatch::from_rows(&[vec![true, false], vec![false, true]]).unwrap();
        let shards = ClauseShard::split(&m, 8).unwrap();
        assert!(shards.iter().any(|s| s.n_slots() == 0), "no empty shard in 8-way toy split");
        let parts: Vec<PartialOutput> =
            shards.iter().map(|s| s.partial(&batch).unwrap()).collect();
        for (s, p) in shards.iter().zip(&parts) {
            if s.n_slots() == 0 {
                assert!(p.sums.iter().all(|&v| v == 0));
                assert_eq!(p.fired.row(0).iter().copied().sum::<u64>(), 0);
            }
        }
        assert_eq!(merge_partials(&parts).unwrap(), m.forward_packed(&batch).unwrap());
    }

    #[test]
    fn scratch_telemetry_accumulates() {
        // Sparse-ish model + all-zero sample: every positive-x index
        // literal reads 0, so the index must skip at least one bucket.
        let m = TmModel::synthetic("telemetry", 2, 20, 32, 0.2, 3);
        let batch = PackedBatch::from_rows(&[vec![false; 32], vec![true; 32]]).unwrap();
        let mut scratch = ForwardScratch::new();
        let out = m.forward_packed_with(&batch, &mut scratch).unwrap();
        assert_eq!(out.batch, 2);
        assert_eq!(scratch.rows, 2);
        assert_eq!(scratch.clauses_eligible, 2 * m.c_total() as u64);
        assert!(scratch.clauses_skipped > 0, "index skipped nothing");
        assert!(scratch.skip_rate() > 0.0 && scratch.skip_rate() <= 1.0);
        scratch.reset();
        assert_eq!(scratch.rows, 0);
        assert_eq!(scratch.skip_rate(), 0.0);
    }
}
