//! Content-addressed artifact store (manifest v2).
//!
//! The v1 artifact tree is a bare directory: `manifest.json` naming whole
//! model files, no checksums, no provenance, whole-model reloads. This
//! module replaces it with the manifest-plus-payload design of artcode
//! RFC 0005 (schema version, per-entry sha256, profile/toolchain
//! provenance) crossed with PB-AI's sharded manifest (per-shard
//! id/kind/bytes/hash):
//!
//! ```text
//! root/
//!   manifest.json              # v2: schema + generation + provenance +
//!                              #     per-model shard records (hash-addressed)
//!   objects/
//!     <sha256-hex>             # clause-block payloads, stored once,
//!     <sha256-hex>             # named by the digest of their bytes
//! ```
//!
//! A model's payload is split into **clause blocks** — contiguous
//! storage-order clause ranges serialized canonically ([`ClauseBlock`]) —
//! and each block lands in `objects/` under its own SHA-256. Two
//! generations that share 9 of 10 blocks share 9 object files, and a
//! reload only has to re-open the block whose hash changed
//! ([`PayloadCache`] makes that delta visible to the coordinator as
//! `reload_shards_reused`). Every object read re-hashes the bytes and
//! fails with a **typed** [`ArtifactError`] on corruption; [`Store::open`]
//! dispatches on the manifest schema so v1 trees stay readable
//! unchanged. [`gc`] removes objects no live generation references,
//! refusing anything pinned by an in-flight open ([`ObjectPin`]).

use std::collections::{BTreeMap, HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{Context, Result};

use crate::util::json::{self, Value};
use crate::util::sha256;

use super::{parse_bits, Manifest, TmModel};

/// Manifest schema tag this module writes and requires for v2 trees.
pub const SCHEMA_V2: &str = "tdpc-artifact/v2";

/// Typed corruption/consistency errors of the artifact store. Returned
/// through `anyhow::Error` everywhere below; callers that need to branch
/// on the failure mode downcast with `err.downcast_ref::<ArtifactError>()`.
#[derive(Debug, Clone, PartialEq)]
pub enum ArtifactError {
    /// An object file's bytes no longer hash to the name/manifest digest
    /// (bit rot, truncation, or a tampered write).
    HashMismatch { object: PathBuf, expected: String, actual: String },
    /// A manifest references an object that is not in the store (a
    /// dangling hash — e.g. GC raced a writer, or a partial copy).
    MissingObject { hash: String, referenced_by: String },
    /// A manifest or payload that does not parse / violates the schema.
    Malformed { path: PathBuf, detail: String },
}

impl std::fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArtifactError::HashMismatch { object, expected, actual } => write!(
                f,
                "corrupt artifact object {}: sha256 {} (manifest expects {})",
                object.display(),
                actual,
                expected
            ),
            ArtifactError::MissingObject { hash, referenced_by } => {
                write!(f, "missing artifact object {hash} (referenced by {referenced_by})")
            }
            ArtifactError::Malformed { path, detail } => {
                write!(f, "malformed artifact {}: {detail}", path.display())
            }
        }
    }
}

impl std::error::Error for ArtifactError {}

fn malformed(path: &Path, detail: impl Into<String>) -> anyhow::Error {
    anyhow::Error::new(ArtifactError::Malformed {
        path: path.to_path_buf(),
        detail: detail.into(),
    })
}

// ---------------------------------------------------------------------------
// Payload: canonical clause blocks
// ---------------------------------------------------------------------------

/// One content-addressed payload shard: a contiguous storage-order clause
/// range `[clause_lo, clause_hi)` of a model. Serialization is canonical
/// (sorted keys, compact emit, bitstring masks) so identical clause data
/// always produces identical bytes — and therefore the same object hash.
#[derive(Debug, Clone, PartialEq)]
pub struct ClauseBlock {
    pub clause_lo: usize,
    pub clause_hi: usize,
    /// Per-clause include masks over `[x, ~x]` literals.
    pub include: Vec<Vec<bool>>,
    pub polarity: Vec<i8>,
    pub nonempty: Vec<bool>,
}

impl ClauseBlock {
    /// Slice a block out of a model's storage-order clause arrays.
    pub fn from_model(m: &TmModel, clause_lo: usize, clause_hi: usize) -> ClauseBlock {
        ClauseBlock {
            clause_lo,
            clause_hi,
            include: m.include[clause_lo..clause_hi].to_vec(),
            polarity: m.polarity[clause_lo..clause_hi].to_vec(),
            nonempty: m.nonempty[clause_lo..clause_hi].to_vec(),
        }
    }

    /// Canonical bytes: compact JSON with BTreeMap-ordered keys. The
    /// object hash is the digest of exactly these bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        fn bitstring(bits: &[bool]) -> Value {
            Value::Str(bits.iter().map(|&b| if b { '1' } else { '0' }).collect())
        }
        let doc = json::obj(vec![
            ("clause_hi", json::num(self.clause_hi as f64)),
            ("clause_lo", json::num(self.clause_lo as f64)),
            ("include", Value::Arr(self.include.iter().map(|row| bitstring(row)).collect())),
            ("kind", json::s(BLOCK_KIND)),
            (
                "nonempty",
                Value::Arr(self.nonempty.iter().map(|&b| json::num(b as u8 as f64)).collect()),
            ),
            (
                "polarity",
                Value::Arr(self.polarity.iter().map(|&p| json::num(p as f64)).collect()),
            ),
        ]);
        json::emit(&doc).into_bytes()
    }

    /// Parse an object payload. `object` names the file for error context.
    pub fn parse(bytes: &[u8], object: &Path) -> Result<ClauseBlock> {
        let text = std::str::from_utf8(bytes)
            .map_err(|_| malformed(object, "payload is not UTF-8"))?;
        let doc = json::parse(text).map_err(|e| malformed(object, format!("bad JSON: {e}")))?;
        let inner = || -> Result<ClauseBlock> {
            let kind = doc.get("kind")?.as_str()?;
            anyhow::ensure!(kind == BLOCK_KIND, "unknown payload kind {kind:?}");
            let clause_lo = doc.get("clause_lo")?.as_usize()?;
            let clause_hi = doc.get("clause_hi")?.as_usize()?;
            let include = doc
                .get("include")?
                .as_arr()?
                .iter()
                .map(|row| parse_bits(row.as_str()?))
                .collect::<Result<Vec<_>>>()?;
            let polarity = doc
                .get("polarity")?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_i64()? as i8))
                .collect::<Result<Vec<_>>>()?;
            let nonempty = doc
                .get("nonempty")?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_i64()? != 0))
                .collect::<Result<Vec<_>>>()?;
            let n = clause_hi.saturating_sub(clause_lo);
            anyhow::ensure!(
                clause_lo < clause_hi
                    && include.len() == n
                    && polarity.len() == n
                    && nonempty.len() == n,
                "clause range [{clause_lo}, {clause_hi}) does not match payload lengths \
                 ({}/{}/{})",
                include.len(),
                polarity.len(),
                nonempty.len()
            );
            Ok(ClauseBlock { clause_lo, clause_hi, include, polarity, nonempty })
        };
        inner().map_err(|e| malformed(object, e.to_string()))
    }
}

/// The only payload kind today. New kinds (automata state for the online
/// trainer, literal stats for reindexing) extend this enum of strings
/// without a schema bump: readers skip kinds they don't know.
pub const BLOCK_KIND: &str = "clause-block";

// ---------------------------------------------------------------------------
// Manifest v2
// ---------------------------------------------------------------------------

/// One shard record of a model: where `[clause_lo, clause_hi)` lives in
/// the object store (PB-AI's per-shard id/kind/bytes/hash).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardRecord {
    /// Stable id, `"<model>/clauses/<i>"`.
    pub id: String,
    pub kind: String,
    pub clause_lo: usize,
    pub clause_hi: usize,
    /// Payload size in bytes (checked before hashing on verify).
    pub bytes: u64,
    /// Lowercase-hex SHA-256 of the payload — also the object file name.
    pub sha256: String,
}

/// One model generation's entry: shape + accuracy + its shard records.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelRecord {
    pub name: String,
    pub n_classes: usize,
    pub n_features: usize,
    pub clauses_per_class: usize,
    pub accuracy: f64,
    pub shards: Vec<ShardRecord>,
}

impl ModelRecord {
    pub fn c_total(&self) -> usize {
        self.n_classes * self.clauses_per_class
    }
}

/// Who wrote the tree, and from what (artcode RFC 0005's
/// profile/toolchain fields).
#[derive(Debug, Clone, PartialEq)]
pub struct Provenance {
    /// Writing toolchain, e.g. `"tdpc 0.1.0"`.
    pub writer: String,
    /// Build profile / flavor of the payloads (`"synthetic"`, `"trained"`).
    pub profile: String,
    /// Where the payloads came from (`"pack"`, `"v1-migration"`, …).
    pub source: String,
}

/// A parsed v2 manifest: the index of one artifact-tree generation.
#[derive(Debug, Clone, PartialEq)]
pub struct StoreManifest {
    pub root: PathBuf,
    /// Monotone per-tree write counter; every `pack`/[`rewrite_shard`]
    /// bumps it, and the coordinator stamps reloads with its own
    /// generation counter on top.
    pub generation: u64,
    pub provenance: Provenance,
    pub models: Vec<ModelRecord>,
}

impl StoreManifest {
    pub fn load(root: &Path) -> Result<StoreManifest> {
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc =
            json::parse(&text).map_err(|e| malformed(&path, format!("bad JSON: {e}")))?;
        Self::from_doc(root, &doc, &path)
    }

    fn from_doc(root: &Path, doc: &Value, path: &Path) -> Result<StoreManifest> {
        let inner = || -> Result<StoreManifest> {
            let schema = doc.get("schema")?.as_str()?;
            anyhow::ensure!(schema == SCHEMA_V2, "unsupported schema {schema:?}");
            let generation = doc.get("generation")?.as_usize()? as u64;
            let prov = doc.get("provenance")?;
            let provenance = Provenance {
                writer: prov.get("writer")?.as_str()?.to_string(),
                profile: prov.get("profile")?.as_str()?.to_string(),
                source: prov.get("source")?.as_str()?.to_string(),
            };
            let mut models = Vec::new();
            for (name, m) in doc.get("models")?.as_obj()? {
                let mut shards = Vec::new();
                for s in m.get("shards")?.as_arr()? {
                    let hash = s.get("sha256")?.as_str()?.to_string();
                    anyhow::ensure!(
                        hash.len() == 64 && hash.bytes().all(|b| b.is_ascii_hexdigit()),
                        "shard {:?} has a malformed sha256 {hash:?}",
                        s.get("id")?.as_str()?
                    );
                    shards.push(ShardRecord {
                        id: s.get("id")?.as_str()?.to_string(),
                        kind: s.get("kind")?.as_str()?.to_string(),
                        clause_lo: s.get("clause_lo")?.as_usize()?,
                        clause_hi: s.get("clause_hi")?.as_usize()?,
                        bytes: s.get("bytes")?.as_usize()? as u64,
                        sha256: hash,
                    });
                }
                models.push(ModelRecord {
                    name: name.clone(),
                    n_classes: m.get("n_classes")?.as_usize()?,
                    n_features: m.get("n_features")?.as_usize()?,
                    clauses_per_class: m.get("clauses_per_class")?.as_usize()?,
                    accuracy: m.get("accuracy")?.as_f64()?,
                    shards,
                });
            }
            models.sort_by(|a, b| a.name.cmp(&b.name));
            Ok(StoreManifest {
                root: root.to_path_buf(),
                generation,
                provenance,
                models,
            })
        };
        inner().map_err(|e| match e.downcast::<ArtifactError>() {
            Ok(typed) => anyhow::Error::new(typed),
            Err(e) => malformed(path, e.to_string()),
        })
    }

    pub fn record(&self, name: &str) -> Result<&ModelRecord> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .with_context(|| format!("model {name:?} not in artifact manifest"))
    }

    fn to_doc(&self) -> Value {
        let models: BTreeMap<String, Value> = self
            .models
            .iter()
            .map(|m| {
                let shards = Value::Arr(
                    m.shards
                        .iter()
                        .map(|s| {
                            json::obj(vec![
                                ("bytes", json::num(s.bytes as f64)),
                                ("clause_hi", json::num(s.clause_hi as f64)),
                                ("clause_lo", json::num(s.clause_lo as f64)),
                                ("id", json::s(&s.id)),
                                ("kind", json::s(&s.kind)),
                                ("sha256", json::s(&s.sha256)),
                            ])
                        })
                        .collect(),
                );
                (
                    m.name.clone(),
                    json::obj(vec![
                        ("accuracy", json::num(m.accuracy)),
                        ("clauses_per_class", json::num(m.clauses_per_class as f64)),
                        ("n_classes", json::num(m.n_classes as f64)),
                        ("n_features", json::num(m.n_features as f64)),
                        ("shards", shards),
                    ]),
                )
            })
            .collect();
        json::obj(vec![
            ("generation", json::num(self.generation as f64)),
            ("models", Value::Obj(models)),
            (
                "provenance",
                json::obj(vec![
                    ("profile", json::s(&self.provenance.profile)),
                    ("source", json::s(&self.provenance.source)),
                    ("writer", json::s(&self.provenance.writer)),
                ]),
            ),
            ("schema", json::s(SCHEMA_V2)),
        ])
    }

    /// Atomic manifest publish: write to a pid-suffixed temp file in the
    /// same directory, then rename over `manifest.json` (readers see the
    /// old manifest or the new one, never a torn write).
    pub fn write(&self) -> Result<()> {
        std::fs::create_dir_all(&self.root)
            .with_context(|| format!("creating {}", self.root.display()))?;
        let path = self.root.join("manifest.json");
        let tmp = self.root.join(format!("manifest.json.tmp.{}", std::process::id()));
        std::fs::write(&tmp, json::emit(&self.to_doc()) + "\n")
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing {}", path.display()))?;
        Ok(())
    }

    /// Every object hash any model of this generation references.
    pub fn referenced_hashes(&self) -> HashSet<String> {
        self.models
            .iter()
            .flat_map(|m| m.shards.iter().map(|s| s.sha256.clone()))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Object store primitives
// ---------------------------------------------------------------------------

fn objects_dir(root: &Path) -> PathBuf {
    root.join("objects")
}

/// Path of the object named `hash` under `root`.
pub fn object_path(root: &Path, hash: &str) -> PathBuf {
    objects_dir(root).join(hash)
}

/// Store `bytes` under its own digest. Returns `(hash, newly_written)`;
/// an object that already exists is never rewritten (content addressing
/// makes the write idempotent). New objects land via temp + rename so a
/// crashed writer cannot leave a half-written object under a valid name.
pub fn write_object(root: &Path, bytes: &[u8]) -> Result<(String, bool)> {
    let hash = sha256::hex_digest(bytes);
    let dir = objects_dir(root);
    std::fs::create_dir_all(&dir).with_context(|| format!("creating {}", dir.display()))?;
    let path = dir.join(&hash);
    if path.exists() {
        return Ok((hash, false));
    }
    let tmp = dir.join(format!("{hash}.tmp.{}", std::process::id()));
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("publishing {}", path.display()))?;
    Ok((hash, true))
}

/// Read and **verify** the object named `hash`. A missing file is a
/// typed [`ArtifactError::MissingObject`]; bytes that do not hash back
/// to the name are a typed [`ArtifactError::HashMismatch`].
pub fn read_object(root: &Path, hash: &str, referenced_by: &str) -> Result<Vec<u8>> {
    let path = object_path(root, hash);
    let bytes = match std::fs::read(&path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Err(anyhow::Error::new(ArtifactError::MissingObject {
                hash: hash.to_string(),
                referenced_by: referenced_by.to_string(),
            }));
        }
        Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
    };
    let actual = sha256::hex_digest(&bytes);
    if actual != hash {
        return Err(anyhow::Error::new(ArtifactError::HashMismatch {
            object: path,
            expected: hash.to_string(),
            actual,
        }));
    }
    Ok(bytes)
}

// ---------------------------------------------------------------------------
// In-flight object pins (GC safety)
// ---------------------------------------------------------------------------

type PinMap = HashMap<(PathBuf, String), usize>;

fn pins() -> &'static Mutex<PinMap> {
    static PINS: OnceLock<Mutex<PinMap>> = OnceLock::new();
    PINS.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Stable per-root key for the pin registry (symlink/relative-path
/// aliases of the same tree must share pins).
fn pin_root_key(root: &Path) -> PathBuf {
    std::fs::canonicalize(root).unwrap_or_else(|_| root.to_path_buf())
}

/// RAII pin on one object of one tree: while any pin is alive, [`gc`]
/// will not delete that object even if no manifest references it (e.g.
/// a worker still serving a superseded generation). Workers hold a pin
/// per cached block for exactly as long as the block is resident
/// ([`PayloadCache`]).
#[derive(Debug)]
pub struct ObjectPin {
    root: PathBuf,
    hash: String,
}

impl Drop for ObjectPin {
    fn drop(&mut self) {
        let mut map = pins().lock().unwrap();
        let key = (self.root.clone(), self.hash.clone());
        if let Some(n) = map.get_mut(&key) {
            *n -= 1;
            if *n == 0 {
                map.remove(&key);
            }
        }
    }
}

/// Pin `hash` under `root` for the lifetime of the returned guard.
pub fn pin_object(root: &Path, hash: &str) -> ObjectPin {
    let root = pin_root_key(root);
    *pins().lock().unwrap().entry((root.clone(), hash.to_string())).or_insert(0) += 1;
    ObjectPin { root, hash: hash.to_string() }
}

/// Hashes currently pinned under `root` (in-flight workers).
pub fn pinned_for(root: &Path) -> HashSet<String> {
    let root = pin_root_key(root);
    pins()
        .lock()
        .unwrap()
        .keys()
        .filter(|(r, _)| *r == root)
        .map(|(_, h)| h.clone())
        .collect()
}

// ---------------------------------------------------------------------------
// Payload cache: the delta-reload mechanism
// ---------------------------------------------------------------------------

struct CacheEntry {
    block: Arc<ClauseBlock>,
    /// Keeps the backing object alive against [`gc`] while cached.
    _pin: ObjectPin,
}

/// Hash-keyed cache of parsed clause blocks, shared by every backend a
/// [`crate::runtime::ModelRegistry`] opens. Because keys are content
/// hashes, a reload whose new manifest repeats a hash is a **cache hit**
/// — no disk read, no re-verify, no re-parse — and the `opened`/`reused`
/// counters are exactly the delta the coordinator reports as
/// `reload_shards_reused`.
#[derive(Default)]
pub struct PayloadCache {
    blocks: Mutex<HashMap<String, CacheEntry>>,
    /// Hashes each model's most recent open referenced (the live set
    /// [`PayloadCache::evict_stale`] retains).
    by_model: Mutex<HashMap<String, Vec<String>>>,
    /// Objects read + verified + parsed from disk.
    opened: AtomicU64,
    /// Cache hits (object bytes not re-read).
    reused: AtomicU64,
}

impl PayloadCache {
    pub fn new() -> PayloadCache {
        PayloadCache::default()
    }

    /// Fetch the block for `rec`, from cache or (verified) from disk.
    pub fn get_or_load(&self, root: &Path, rec: &ShardRecord) -> Result<Arc<ClauseBlock>> {
        if let Some(e) = self.blocks.lock().unwrap().get(&rec.sha256) {
            self.reused.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(&e.block));
        }
        let block = Arc::new(load_block(root, rec)?);
        self.opened.fetch_add(1, Ordering::Relaxed);
        let pin = pin_object(root, &rec.sha256);
        self.blocks
            .lock()
            .unwrap()
            .entry(rec.sha256.clone())
            .or_insert(CacheEntry { block: Arc::clone(&block), _pin: pin });
        Ok(block)
    }

    /// Record the hashes model `name`'s latest open referenced.
    pub fn note_model(&self, name: &str, hashes: Vec<String>) {
        self.by_model.lock().unwrap().insert(name.to_string(), hashes);
    }

    /// Drop cached blocks (and their GC pins) that no model's latest
    /// open references — called after a successful swap so superseded
    /// generations release their objects.
    pub fn evict_stale(&self) {
        let live: HashSet<String> =
            self.by_model.lock().unwrap().values().flatten().cloned().collect();
        self.blocks.lock().unwrap().retain(|hash, _| live.contains(hash));
    }

    /// `(opened, reused)` lifetime counters.
    pub fn stats(&self) -> (u64, u64) {
        (self.opened.load(Ordering::Relaxed), self.reused.load(Ordering::Relaxed))
    }
}

/// Read + verify + parse one shard record's payload (no cache).
fn load_block(root: &Path, rec: &ShardRecord) -> Result<ClauseBlock> {
    let bytes = read_object(root, &rec.sha256, &rec.id)?;
    let path = object_path(root, &rec.sha256);
    if bytes.len() as u64 != rec.bytes {
        return Err(malformed(
            &path,
            format!("object is {} bytes, manifest records {}", bytes.len(), rec.bytes),
        ));
    }
    let block = ClauseBlock::parse(&bytes, &path)?;
    if block.clause_lo != rec.clause_lo || block.clause_hi != rec.clause_hi {
        return Err(malformed(
            &path,
            format!(
                "payload covers clauses [{}, {}) but record {} says [{}, {})",
                block.clause_lo, block.clause_hi, rec.id, rec.clause_lo, rec.clause_hi
            ),
        ));
    }
    Ok(block)
}

// ---------------------------------------------------------------------------
// Store: version-dispatching open + model loading
// ---------------------------------------------------------------------------

/// An opened artifact tree, v1 or v2. [`Store::open`] dispatches on the
/// manifest's `schema` field, so every caller that used to call
/// `Manifest::load` keeps working on old trees while new trees get hash
/// verification and delta-aware payload loading.
#[derive(Debug, Clone)]
pub enum Store {
    /// Legacy bare-directory tree (`Manifest::load`): whole-model JSON
    /// files, no hashes. Read-only compatibility path.
    V1(Manifest),
    /// Content-addressed tree (this module).
    V2(StoreManifest),
}

impl Store {
    pub fn open(root: &Path) -> Result<Store> {
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (artifact tree root?)", path.display()))?;
        let doc =
            json::parse(&text).map_err(|e| malformed(&path, format!("bad JSON: {e}")))?;
        if doc.get_opt("schema").is_some() {
            return Ok(Store::V2(StoreManifest::from_doc(root, &doc, &path)?));
        }
        if doc.get_opt("batch_sizes").is_some() {
            return Ok(Store::V1(Manifest::load(root)?));
        }
        Err(malformed(&path, "neither a v2 manifest (schema) nor a v1 manifest (batch_sizes)"))
    }

    pub fn root(&self) -> &Path {
        match self {
            Store::V1(m) => &m.root,
            Store::V2(m) => &m.root,
        }
    }

    pub fn is_v2(&self) -> bool {
        matches!(self, Store::V2(_))
    }

    /// The v1 view, if this is a v1 tree (HLO paths, batch sizes, test
    /// data — fields v2 does not carry).
    pub fn v1(&self) -> Option<&Manifest> {
        match self {
            Store::V1(m) => Some(m),
            Store::V2(_) => None,
        }
    }

    pub fn v2(&self) -> Option<&StoreManifest> {
        match self {
            Store::V1(_) => None,
            Store::V2(m) => Some(m),
        }
    }

    /// Store generation (v1 trees have none; reported as 0).
    pub fn generation(&self) -> u64 {
        match self {
            Store::V1(_) => 0,
            Store::V2(m) => m.generation,
        }
    }

    pub fn model_names(&self) -> Vec<String> {
        match self {
            Store::V1(m) => m.models.iter().map(|e| e.name.clone()).collect(),
            Store::V2(m) => m.models.iter().map(|r| r.name.clone()).collect(),
        }
    }

    /// Shape of one model without loading payloads:
    /// `(n_classes, n_features, clauses_per_class, accuracy)`.
    pub fn model_shape(&self, name: &str) -> Result<(usize, usize, usize, f64)> {
        match self {
            Store::V1(m) => {
                let e = m.entry(name)?;
                Ok((e.n_classes, e.n_features, e.clauses_per_class, e.accuracy))
            }
            Store::V2(m) => {
                let r = m.record(name)?;
                Ok((r.n_classes, r.n_features, r.clauses_per_class, r.accuracy))
            }
        }
    }

    /// Load a full model. v2 trees verify every object hash on the way
    /// in; a `cache` turns repeat hashes into no-disk-touch hits.
    pub fn load_model(&self, name: &str, cache: Option<&PayloadCache>) -> Result<TmModel> {
        match self {
            Store::V1(m) => {
                let entry = m.entry(name)?;
                let mut model = TmModel::load(&entry.model_path)?;
                model.name = entry.name.clone();
                Ok(model)
            }
            Store::V2(m) => {
                let rec = m.record(name)?;
                let blocks = self.fetch_blocks(rec, &rec.shards, cache)?;
                if let Some(c) = cache {
                    c.note_model(name, rec.shards.iter().map(|s| s.sha256.clone()).collect());
                }
                assemble_from_blocks(rec, &blocks, None)
            }
        }
    }

    /// Load only the clause range shard `index`-of-`n_shards` owns
    /// (`[i·C/n, (i+1)·C/n)`), touching only the objects that overlap
    /// it — the "a shard worker opens only its own bytes" path. Clauses
    /// outside the range come back **dead** (`nonempty = false`), so a
    /// `ClauseShard` built over the owned range produces partial sums
    /// bit-identical to a slice of the full model. v2 trees only.
    pub fn load_model_subset(
        &self,
        name: &str,
        index: usize,
        n_shards: usize,
        cache: Option<&PayloadCache>,
    ) -> Result<TmModel> {
        let m = match self {
            Store::V1(_) => anyhow::bail!(
                "subset loads need a v2 artifact tree (run `tdpc pack --from-v1`)"
            ),
            Store::V2(m) => m,
        };
        anyhow::ensure!(index < n_shards, "shard {index} out of range ({n_shards} shards)");
        let rec = m.record(name)?;
        let c_total = rec.c_total();
        let lo = index * c_total / n_shards;
        let hi = (index + 1) * c_total / n_shards;
        let wanted: Vec<ShardRecord> = rec
            .shards
            .iter()
            .filter(|s| s.clause_lo < hi && s.clause_hi > lo)
            .cloned()
            .collect();
        let blocks = self.fetch_blocks(rec, &wanted, cache)?;
        if let Some(c) = cache {
            c.note_model(
                &format!("{name}#{index}/{n_shards}"),
                wanted.iter().map(|s| s.sha256.clone()).collect(),
            );
        }
        assemble_from_blocks(rec, &blocks, Some((lo, hi)))
    }

    fn fetch_blocks(
        &self,
        rec: &ModelRecord,
        shards: &[ShardRecord],
        cache: Option<&PayloadCache>,
    ) -> Result<Vec<Arc<ClauseBlock>>> {
        let root = self.root();
        shards
            .iter()
            .map(|s| match cache {
                Some(c) => c.get_or_load(root, s),
                None => load_block(root, s).map(Arc::new),
            })
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("loading payload of model {:?}", rec.name))
    }
}

/// Assemble a [`TmModel`] from clause blocks. With `owned = Some((lo,
/// hi))` only clauses in `[lo, hi)` are materialized (the rest stay
/// all-zero and dead); coverage of the owned range must be exact — a
/// gap or an overlap is a typed malformed-artifact error.
fn assemble_from_blocks(
    rec: &ModelRecord,
    blocks: &[Arc<ClauseBlock>],
    owned: Option<(usize, usize)>,
) -> Result<TmModel> {
    let c_total = rec.c_total();
    let (lo, hi) = owned.unwrap_or((0, c_total));
    let width = 2 * rec.n_features;
    let mut include = vec![vec![false; width]; c_total];
    let mut polarity = vec![1i8; c_total];
    let mut nonempty = vec![false; c_total];
    let mut covered = vec![false; c_total];
    let err = |detail: String| {
        malformed(&PathBuf::from(format!("model {}", rec.name)), detail)
    };
    for b in blocks {
        if b.clause_hi > c_total {
            return Err(err(format!(
                "block [{}, {}) exceeds {} clauses",
                b.clause_lo, b.clause_hi, c_total
            )));
        }
        for (off, c) in (b.clause_lo..b.clause_hi).enumerate() {
            if c < lo || c >= hi {
                continue;
            }
            if covered[c] {
                return Err(err(format!("clause {c} covered by two blocks")));
            }
            covered[c] = true;
            if b.include[off].len() != width {
                return Err(err(format!(
                    "clause {c} has {} literals, model width is {width}",
                    b.include[off].len()
                )));
            }
            include[c] = b.include[off].clone();
            polarity[c] = b.polarity[off];
            nonempty[c] = b.nonempty[off];
        }
    }
    if let Some(c) = (lo..hi).find(|&c| !covered[c]) {
        return Err(err(format!("clause {c} not covered by any block")));
    }
    Ok(TmModel::assemble(
        rec.name.clone(),
        rec.n_classes,
        rec.n_features,
        rec.clauses_per_class,
        include,
        polarity,
        nonempty,
        rec.accuracy,
    ))
}

// ---------------------------------------------------------------------------
// Pack / verify / GC / rewrite
// ---------------------------------------------------------------------------

/// Options for [`pack`].
#[derive(Debug, Clone)]
pub struct PackOptions {
    /// Clause blocks per model (each becomes one object). Clamped to
    /// `[1, c_total]` per model.
    pub n_shards: usize,
    pub profile: String,
    pub source: String,
}

impl Default for PackOptions {
    fn default() -> Self {
        PackOptions { n_shards: 4, profile: "synthetic".into(), source: "pack".into() }
    }
}

/// What [`pack`] did.
#[derive(Debug, Clone, PartialEq)]
pub struct PackReport {
    pub models: usize,
    /// Objects newly written to the store.
    pub objects_written: usize,
    /// Objects that already existed (content-hash dedup hits).
    pub objects_deduped: usize,
    pub bytes_written: u64,
    /// Generation of the manifest this pack published.
    pub generation: u64,
}

fn default_writer() -> String {
    format!("tdpc {}", env!("CARGO_PKG_VERSION"))
}

/// Pack `models` into a v2 tree at `root`: split each model's clause
/// arrays into `opts.n_shards` contiguous blocks, store each block once
/// under its content hash, and publish a new manifest generation
/// atomically. Re-packing unchanged models writes zero new objects.
pub fn pack(root: &Path, models: &[&TmModel], opts: &PackOptions) -> Result<PackReport> {
    let generation = match StoreManifest::load(root) {
        Ok(prev) => prev.generation + 1,
        Err(_) => 1,
    };
    let mut records = Vec::with_capacity(models.len());
    let mut written = 0usize;
    let mut deduped = 0usize;
    let mut bytes_written = 0u64;
    for m in models {
        anyhow::ensure!(
            !m.name.is_empty()
                && m.name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-'),
            "artifact model names must be [A-Za-z0-9_-]+, got {:?}",
            m.name
        );
        let c_total = m.c_total();
        anyhow::ensure!(c_total > 0, "model {:?} has no clauses", m.name);
        let n = opts.n_shards.clamp(1, c_total);
        let mut shards = Vec::with_capacity(n);
        for i in 0..n {
            let lo = i * c_total / n;
            let hi = (i + 1) * c_total / n;
            let payload = ClauseBlock::from_model(m, lo, hi).to_bytes();
            let (hash, new) = write_object(root, &payload)?;
            if new {
                written += 1;
                bytes_written += payload.len() as u64;
            } else {
                deduped += 1;
            }
            shards.push(ShardRecord {
                id: format!("{}/clauses/{i}", m.name),
                kind: BLOCK_KIND.to_string(),
                clause_lo: lo,
                clause_hi: hi,
                bytes: payload.len() as u64,
                sha256: hash,
            });
        }
        records.push(ModelRecord {
            name: m.name.clone(),
            n_classes: m.n_classes,
            n_features: m.n_features,
            clauses_per_class: m.clauses_per_class,
            accuracy: m.accuracy,
            shards,
        });
    }
    records.sort_by(|a, b| a.name.cmp(&b.name));
    let manifest = StoreManifest {
        root: root.to_path_buf(),
        generation,
        provenance: Provenance {
            writer: default_writer(),
            profile: opts.profile.clone(),
            source: opts.source.clone(),
        },
        models: records,
    };
    manifest.write()?;
    Ok(PackReport {
        models: models.len(),
        objects_written: written,
        objects_deduped: deduped,
        bytes_written,
        generation: manifest.generation,
    })
}

/// Convert a v1 tree **in place**: load every model the v1 manifest
/// names, pack them as content-addressed blocks, and publish a v2
/// manifest over the old one. The v1 `models/` files are left behind
/// (they are not objects; `gc` ignores them) so the conversion is easy
/// to inspect. `load(v1) == load(pack_from_v1(v1))` by construction —
/// the round-trip property test in `tests/artifact_store.rs`.
pub fn pack_from_v1(root: &Path, n_shards: usize) -> Result<PackReport> {
    let v1 = Manifest::load(root).context("pack --from-v1 needs a loadable v1 manifest")?;
    let mut models = Vec::with_capacity(v1.models.len());
    for entry in &v1.models {
        let mut m = TmModel::load(&entry.model_path)
            .with_context(|| format!("loading v1 model {:?}", entry.name))?;
        m.name = entry.name.clone();
        models.push(m);
    }
    let refs: Vec<&TmModel> = models.iter().collect();
    pack(
        root,
        &refs,
        &PackOptions {
            n_shards,
            profile: "v1".into(),
            source: "v1-migration".into(),
        },
    )
}

/// What [`verify`] checked.
#[derive(Debug, Clone, PartialEq)]
pub struct VerifyReport {
    pub models: usize,
    /// Shard objects read, size-checked, re-hashed, and parsed.
    pub objects_verified: usize,
    pub bytes_verified: u64,
    /// Objects in the store no current-manifest shard references
    /// (candidates for [`gc`], not an error).
    pub unreferenced: usize,
}

/// Full-tree integrity check of a v2 tree: every shard record's object
/// must exist, match its recorded size, hash back to its name, parse as
/// its kind, and assemble into a well-formed model. Any violation is a
/// typed [`ArtifactError`].
pub fn verify(root: &Path) -> Result<VerifyReport> {
    let store = Store::open(root)?;
    let m = match &store {
        Store::V1(_) => anyhow::bail!(
            "{} is a v1 tree (no hashes to verify) — run `tdpc pack --from-v1` first",
            root.display()
        ),
        Store::V2(m) => m,
    };
    let mut objects = 0usize;
    let mut bytes = 0u64;
    for rec in &m.models {
        for s in &rec.shards {
            let block = load_block(root, s)?;
            objects += 1;
            bytes += s.bytes;
            drop(block);
        }
        // The blocks must also assemble into a coherent model (coverage,
        // widths) — re-reads via load_model keep this path identical to
        // what serving does at open.
        store.load_model(&rec.name, None)?;
    }
    let referenced = m.referenced_hashes();
    let unreferenced = list_objects(root)?
        .into_iter()
        .filter(|(h, _)| !referenced.contains(h))
        .count();
    Ok(VerifyReport {
        models: m.models.len(),
        objects_verified: objects,
        bytes_verified: bytes,
        unreferenced,
    })
}

/// `(hash, size_bytes)` of every object file in the store. Only names
/// that look like sha256 hex are objects; temp files and strays are
/// ignored (and never GC'd).
fn list_objects(root: &Path) -> Result<Vec<(String, u64)>> {
    let dir = objects_dir(root);
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(&dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
        Err(e) => return Err(e).with_context(|| format!("listing {}", dir.display())),
    };
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if name.len() == 64 && name.bytes().all(|b| b.is_ascii_hexdigit()) {
            let size = entry.metadata().map(|m| m.len()).unwrap_or(0);
            out.push((name, size));
        }
    }
    out.sort();
    Ok(out)
}

/// What [`gc`] did (or would do, under `dry_run`).
#[derive(Debug, Clone, PartialEq)]
pub struct GcReport {
    /// Objects in the store.
    pub scanned: usize,
    /// Objects referenced by the live manifest generation.
    pub live: usize,
    /// Unreferenced objects kept because an in-flight worker pins them.
    pub kept_pinned: usize,
    /// Objects deleted (or that would be, under `dry_run`).
    pub deleted: usize,
    pub bytes_freed: u64,
}

/// Delete objects no live generation references. The live set is the
/// union of (a) every hash the current manifest references and (b)
/// every hash pinned by an in-process open ([`pin_object`]) — so a
/// worker still serving a superseded generation never loses its bytes.
pub fn gc(root: &Path, dry_run: bool) -> Result<GcReport> {
    let store = Store::open(root)?;
    let referenced = match &store {
        Store::V1(_) => anyhow::bail!(
            "{} is a v1 tree (no object store to collect) — run `tdpc pack --from-v1` first",
            root.display()
        ),
        Store::V2(m) => m.referenced_hashes(),
    };
    let pinned = pinned_for(root);
    let mut report = GcReport { scanned: 0, live: 0, kept_pinned: 0, deleted: 0, bytes_freed: 0 };
    for (hash, size) in list_objects(root)? {
        report.scanned += 1;
        if referenced.contains(&hash) {
            report.live += 1;
            continue;
        }
        if pinned.contains(&hash) {
            report.kept_pinned += 1;
            continue;
        }
        if !dry_run {
            std::fs::remove_file(object_path(root, &hash))
                .with_context(|| format!("deleting object {hash}"))?;
        }
        report.deleted += 1;
        report.bytes_freed += size;
    }
    Ok(report)
}

/// Rewrite one shard of one model: load its block, apply `mutate`,
/// store the result as a new object, and publish a bumped-generation
/// manifest pointing at it. The old object stays in the store (a live
/// pool may still serve it) until [`gc`]. Returns the new object hash.
///
/// This is the minimal "one shard changed" writer that delta-reload
/// tests, `serve --mutate-shard`, and the artifact bench drive.
pub fn rewrite_shard(
    root: &Path,
    model: &str,
    shard_ix: usize,
    mutate: impl FnOnce(&mut ClauseBlock),
) -> Result<String> {
    let mut manifest = StoreManifest::load(root)?;
    let rec = manifest
        .models
        .iter_mut()
        .find(|m| m.name == model)
        .with_context(|| format!("model {model:?} not in artifact manifest"))?;
    anyhow::ensure!(
        shard_ix < rec.shards.len(),
        "shard {shard_ix} out of range ({} shards)",
        rec.shards.len()
    );
    let mut block = load_block(root, &rec.shards[shard_ix])?;
    mutate(&mut block);
    anyhow::ensure!(
        block.clause_lo == rec.shards[shard_ix].clause_lo
            && block.clause_hi == rec.shards[shard_ix].clause_hi,
        "mutate must not change the shard's clause range"
    );
    let payload = block.to_bytes();
    let (hash, _) = write_object(root, &payload)?;
    rec.shards[shard_ix].sha256 = hash.clone();
    rec.shards[shard_ix].bytes = payload.len() as u64;
    manifest.generation += 1;
    manifest.write()?;
    Ok(hash)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_root(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("tdpc-artifact-{tag}-{}", std::process::id()))
    }

    fn two_models() -> (TmModel, TmModel) {
        (
            TmModel::synthetic("tenant_a", 3, 8, 17, 0.25, 11),
            TmModel::synthetic("tenant_b", 2, 6, 33, 0.3, 12),
        )
    }

    fn models_equal(a: &TmModel, b: &TmModel) -> bool {
        a.n_classes == b.n_classes
            && a.n_features == b.n_features
            && a.clauses_per_class == b.clauses_per_class
            && a.include == b.include
            && a.polarity == b.polarity
            && a.nonempty == b.nonempty
    }

    #[test]
    fn clause_block_bytes_are_canonical_and_roundtrip() {
        let (a, _) = two_models();
        let block = ClauseBlock::from_model(&a, 3, 9);
        let bytes = block.to_bytes();
        assert_eq!(bytes, block.to_bytes(), "serialization must be deterministic");
        let parsed = ClauseBlock::parse(&bytes, Path::new("test")).unwrap();
        assert_eq!(parsed, block);
        // Any content change must change the bytes (and thus the hash).
        let mut mutated = block.clone();
        mutated.include[0][0] = !mutated.include[0][0];
        assert_ne!(mutated.to_bytes(), bytes);
        assert_ne!(
            sha256::hex_digest(&mutated.to_bytes()),
            sha256::hex_digest(&bytes)
        );
    }

    #[test]
    fn pack_open_roundtrip_and_dedup() {
        let root = temp_root("roundtrip");
        std::fs::remove_dir_all(&root).ok();
        let (a, b) = two_models();
        let opts = PackOptions { n_shards: 4, ..Default::default() };
        let r1 = pack(&root, &[&a, &b], &opts).unwrap();
        assert_eq!(r1.generation, 1);
        assert_eq!(r1.objects_written, 8);
        assert_eq!(r1.objects_deduped, 0);
        let store = Store::open(&root).unwrap();
        assert!(store.is_v2());
        assert_eq!(store.model_names(), vec!["tenant_a", "tenant_b"]);
        let la = store.load_model("tenant_a", None).unwrap();
        assert!(models_equal(&la, &a));
        // Re-packing identical content writes zero new objects.
        let r2 = pack(&root, &[&a, &b], &opts).unwrap();
        assert_eq!(r2.generation, 2);
        assert_eq!(r2.objects_written, 0);
        assert_eq!(r2.objects_deduped, 8);
        // Verify passes and sees no garbage.
        let v = verify(&root).unwrap();
        assert_eq!((v.models, v.objects_verified, v.unreferenced), (2, 8, 0));
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn flipped_byte_is_a_typed_hash_mismatch() {
        let root = temp_root("corrupt");
        std::fs::remove_dir_all(&root).ok();
        let (a, _) = two_models();
        pack(&root, &[&a], &PackOptions::default()).unwrap();
        let m = StoreManifest::load(&root).unwrap();
        let hash = &m.models[0].shards[0].sha256;
        let path = object_path(&root, hash);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x20;
        std::fs::write(&path, &bytes).unwrap();
        let err = Store::open(&root).unwrap().load_model("tenant_a", None).unwrap_err();
        match err.downcast_ref::<ArtifactError>() {
            Some(ArtifactError::HashMismatch { expected, actual, .. }) => {
                assert_eq!(expected, hash);
                assert_ne!(actual, hash);
            }
            other => panic!("expected HashMismatch, got {other:?} ({err:#})"),
        }
        assert!(verify(&root).is_err());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn dangling_hash_is_a_typed_missing_object() {
        let root = temp_root("dangling");
        std::fs::remove_dir_all(&root).ok();
        let (a, _) = two_models();
        pack(&root, &[&a], &PackOptions::default()).unwrap();
        let m = StoreManifest::load(&root).unwrap();
        std::fs::remove_file(object_path(&root, &m.models[0].shards[1].sha256)).unwrap();
        let err = Store::open(&root).unwrap().load_model("tenant_a", None).unwrap_err();
        match err.downcast_ref::<ArtifactError>() {
            Some(ArtifactError::MissingObject { referenced_by, .. }) => {
                assert_eq!(referenced_by, "tenant_a/clauses/1");
            }
            other => panic!("expected MissingObject, got {other:?} ({err:#})"),
        }
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn truncated_manifest_is_typed_malformed() {
        let root = temp_root("truncated");
        std::fs::remove_dir_all(&root).ok();
        let (a, _) = two_models();
        pack(&root, &[&a], &PackOptions::default()).unwrap();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() / 2]).unwrap();
        let err = Store::open(&root).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ArtifactError>(), Some(ArtifactError::Malformed { .. })),
            "expected Malformed, got {err:#}"
        );
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn subset_load_matches_full_model_slice() {
        let root = temp_root("subset");
        std::fs::remove_dir_all(&root).ok();
        let (a, _) = two_models();
        pack(&root, &[&a], &PackOptions { n_shards: 4, ..Default::default() }).unwrap();
        let store = Store::open(&root).unwrap();
        let n_shards = 3; // deliberately misaligned with the 4 packed blocks
        let c_total = a.c_total();
        let mut nonempty_seen = vec![false; c_total];
        for i in 0..n_shards {
            let sub = store.load_model_subset("tenant_a", i, n_shards, None).unwrap();
            let (lo, hi) = (i * c_total / n_shards, (i + 1) * c_total / n_shards);
            for c in 0..c_total {
                if c >= lo && c < hi {
                    assert_eq!(sub.include[c], a.include[c], "clause {c} shard {i}");
                    assert_eq!(sub.polarity[c], a.polarity[c]);
                    assert_eq!(sub.nonempty[c], a.nonempty[c]);
                    if sub.nonempty[c] {
                        assert!(!nonempty_seen[c], "clause {c} live in two shards");
                        nonempty_seen[c] = true;
                    }
                } else {
                    assert!(!sub.nonempty[c], "clause {c} must be dead outside shard {i}");
                }
            }
        }
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn payload_cache_counts_delta_and_pins_survive_gc() {
        let root = temp_root("cache");
        std::fs::remove_dir_all(&root).ok();
        let (a, _) = two_models();
        pack(&root, &[&a], &PackOptions { n_shards: 4, ..Default::default() }).unwrap();
        let cache = PayloadCache::new();
        let store = Store::open(&root).unwrap();
        store.load_model("tenant_a", Some(&cache)).unwrap();
        assert_eq!(cache.stats(), (4, 0));
        // Rewrite one shard: re-open touches exactly one object.
        rewrite_shard(&root, "tenant_a", 2, |b| {
            let c = b.nonempty.iter().position(|&x| !x).unwrap_or(0);
            b.include[c][0] = !b.include[c][0];
        })
        .unwrap();
        let store = Store::open(&root).unwrap();
        store.load_model("tenant_a", Some(&cache)).unwrap();
        assert_eq!(cache.stats(), (5, 3), "delta reload must re-open exactly 1 of 4");
        // The superseded object is unreferenced but pinned by the cache.
        let dry = gc(&root, true).unwrap();
        assert_eq!((dry.scanned, dry.live, dry.kept_pinned, dry.deleted), (5, 4, 1, 0));
        // Evicting stale blocks releases the pin; gc can then collect.
        cache.evict_stale();
        let swept = gc(&root, false).unwrap();
        assert_eq!((swept.kept_pinned, swept.deleted), (0, 1));
        assert_eq!(list_objects(&root).unwrap().len(), 4);
        // Everything still referenced still loads.
        Store::open(&root).unwrap().load_model("tenant_a", None).unwrap();
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn gc_never_deletes_referenced_objects() {
        let root = temp_root("gc-ref");
        std::fs::remove_dir_all(&root).ok();
        let (a, b) = two_models();
        pack(&root, &[&a, &b], &PackOptions::default()).unwrap();
        let before = list_objects(&root).unwrap();
        let swept = gc(&root, false).unwrap();
        assert_eq!(swept.deleted, 0);
        assert_eq!(swept.live, before.len());
        assert_eq!(list_objects(&root).unwrap(), before);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn rewrite_shard_bumps_generation_and_changes_one_hash() {
        let root = temp_root("rewrite");
        std::fs::remove_dir_all(&root).ok();
        let (a, _) = two_models();
        pack(&root, &[&a], &PackOptions { n_shards: 4, ..Default::default() }).unwrap();
        let before = StoreManifest::load(&root).unwrap();
        let new_hash = rewrite_shard(&root, "tenant_a", 1, |blk| {
            blk.polarity[0] = -blk.polarity[0];
        })
        .unwrap();
        let after = StoreManifest::load(&root).unwrap();
        assert_eq!(after.generation, before.generation + 1);
        let (mb, ma) = (&before.models[0], &after.models[0]);
        for i in 0..4 {
            if i == 1 {
                assert_eq!(ma.shards[i].sha256, new_hash);
                assert_ne!(ma.shards[i].sha256, mb.shards[i].sha256);
            } else {
                assert_eq!(ma.shards[i].sha256, mb.shards[i].sha256);
            }
        }
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn v1_trees_open_through_the_store() {
        let root = temp_root("v1-compat");
        std::fs::remove_dir_all(&root).ok();
        let (a, b) = two_models();
        Manifest::write_synthetic(&root, &[&a, &b]).unwrap();
        let store = Store::open(&root).unwrap();
        assert!(!store.is_v2());
        assert!(store.v1().is_some());
        let la = store.load_model("tenant_a", None).unwrap();
        assert!(models_equal(&la, &a));
        assert!(store.load_model_subset("tenant_a", 0, 2, None).is_err());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn pack_from_v1_roundtrips() {
        let root = temp_root("from-v1");
        std::fs::remove_dir_all(&root).ok();
        let (a, b) = two_models();
        Manifest::write_synthetic(&root, &[&a, &b]).unwrap();
        let v1_a = Store::open(&root).unwrap().load_model("tenant_a", None).unwrap();
        let report = pack_from_v1(&root, 4).unwrap();
        assert_eq!(report.models, 2);
        let store = Store::open(&root).unwrap();
        assert!(store.is_v2());
        let v2_a = store.load_model("tenant_a", None).unwrap();
        assert!(models_equal(&v1_a, &v2_a), "load(v1) == load(pack(v1))");
        verify(&root).unwrap();
        std::fs::remove_dir_all(&root).ok();
    }
}
