//! FPT'18 baseline (Kim et al. [6]): ripple-carry-style popcount.
//!
//! The original optimizes BNN popcount with a chained structure where the
//! critical path grows *linearly* with the input width (the paper's Fig.
//! 10a), in exchange for fewer LUTs than a full adder tree. We reconstruct
//! it inside the same synchronous TM shell the paper used: clause blocks →
//! FPT'18 popcount per class → sequential argmax.

use crate::util::Ps;

use super::adder_tree::ADDER_GLITCH;
use super::{
    calib, clause_block, comparator, Architecture, DesignParams, LatencyBreakdown,
    ResourceBreakdown, ToggleInventory,
};

#[derive(Debug, Clone, Copy, Default)]
pub struct Fpt18;

impl Fpt18 {
    /// Linear-chain popcount delay per class: the carry/sum chain threads
    /// every clause bit (the worst case — an increment at position 0).
    pub fn popcount_delay(d: &DesignParams, m: f64) -> Ps {
        Self::popcount_settle(d, m, d.clauses_per_class.max(1))
    }

    /// Per-request settle time of the ripple chain: the recomputation wave
    /// must thread every stage up to the furthest fired clause position
    /// (`active`, 1-based; ≤ clauses/class) — stages beyond it see no new
    /// increment and contribute only the fixed epilogue term. Evaluated by
    /// [`crate::hw::SyncReplayEngine`] with each sample's actual fired
    /// positions.
    pub fn popcount_settle(d: &DesignParams, m: f64, active: usize) -> Ps {
        let n = active.clamp(1, d.clauses_per_class.max(1)) as u64;
        Ps(calib::FPT18_PER_BIT.0 * n + calib::LUT_D.0 + calib::NET_LOCAL.0).scale(m)
    }

    /// The resource win over the generic tree: ~0.65 LUT/bit plus the
    /// signed combine.
    pub fn popcount_luts(d: &DesignParams) -> u32 {
        let per_class =
            (d.clauses_per_class as f64 * 0.65).ceil() as u32 + d.sum_width() as u32;
        per_class * d.n_classes as u32
    }

    fn ffs(d: &DesignParams) -> u32 {
        (d.n_features + d.c_total() + d.n_classes * d.sum_width() + 4) as u32
    }
}

impl Architecture for Fpt18 {
    fn name(&self) -> &'static str {
        "fpt18"
    }

    fn latency(&self, d: &DesignParams) -> LatencyBreakdown {
        let m = calib::congestion(self.resources(d).luts());
        LatencyBreakdown {
            clause: clause_block::clause_delay(d, m),
            popcount: Self::popcount_delay(d, m),
            compare: comparator::compare_delay(d, m),
            control: calib::SYNC_CLOCK_MARGIN,
        }
    }

    fn resources(&self, d: &DesignParams) -> ResourceBreakdown {
        ResourceBreakdown {
            clause_luts: clause_block::clause_luts(d),
            popcount_luts: Self::popcount_luts(d),
            compare_luts: comparator::compare_luts(d),
            control_luts: 8,
            ffs: Self::ffs(d),
        }
    }

    fn toggles(&self, d: &DesignParams, activity: f64) -> ToggleInventory {
        ToggleInventory {
            clause_toggles_per_inference: clause_block::clause_toggles(d, activity),
            // Ripple chains glitch less than trees (shorter reconvergent
            // paths) — the basis of Fig. 9c's "FPT'18 popcount itself has
            // lower dynamic power" observation.
            popcount_toggles_per_inference: Self::popcount_luts(d) as f64
                * activity
                * (ADDER_GLITCH * 0.6),
            compare_toggles_per_inference: comparator::compare_toggles(d, ADDER_GLITCH)
                * activity.max(0.25),
            clocked_ffs: Self::ffs(d),
            control_toggles_per_inference: 4.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn popcount_latency_is_linear() {
        let t100 = Fpt18::popcount_delay(&DesignParams::synthetic(6, 100, 200), 1.0);
        let t200 = Fpt18::popcount_delay(&DesignParams::synthetic(6, 200, 200), 1.0);
        let ratio = t200.as_ps_f64() / t100.as_ps_f64();
        assert!((1.9..2.05).contains(&ratio), "linear in clauses, got {ratio}");
    }

    #[test]
    fn fewer_popcount_luts_than_generic() {
        use super::super::adder_tree::GenericAdder;
        let d = DesignParams::synthetic(10, 100, 784);
        assert!(Fpt18::popcount_luts(&d) < GenericAdder::popcount_luts(&d));
    }

    #[test]
    fn slower_than_generic_at_scale() {
        // [6] trades latency for resources; at 100+ clauses the linear
        // chain must be slower than the log tree.
        use super::super::adder_tree::GenericAdder;
        let d = DesignParams::synthetic(6, 200, 200);
        assert!(
            Fpt18::popcount_delay(&d, 1.0) > GenericAdder::popcount_delay(&d, 1.0)
        );
    }

    #[test]
    fn popcount_power_below_generic() {
        use super::super::adder_tree::GenericAdder;
        let d = DesignParams::synthetic(10, 50, 784);
        let f = Fpt18.toggles(&d, 0.3);
        let g = GenericAdder.toggles(&d, 0.3);
        assert!(f.popcount_toggles_per_inference < g.popcount_toggles_per_inference);
    }
}
