//! Calibration constants for the synchronous baseline architectures.
//!
//! The paper's absolute numbers come from Vivado implementation runs on the
//! authors' board; this reproduction derives them from structural models
//! (tree depths, chain lengths, per-level LUT + routing delays) whose
//! constants are calibrated so the paper's *reported relationships* hold:
//! who wins, by roughly what factor, and where the crossovers fall
//! (DESIGN.md §4 "shape targets"). The structural scaling laws — log-depth
//! adder trees, linear ripple chains, linear sequential comparison, linear
//! PDLs — are what the experiments actually probe; these constants only
//! anchor the scale. All in one place so the calibration is auditable.

use crate::util::Ps;

/// Logic delay through one LUT6 (same constant as the fabric model).
pub const LUT_D: Ps = crate::fabric::LUT_LOGIC_DELAY; // 124 ps

/// Local routed net between neighbouring logic levels (uncongested).
pub const NET_LOCAL: Ps = Ps(290);

/// Net delay of a high-fanout feature-distribution level (before the
/// congestion multiplier): Boolean inputs fan out to every clause block.
pub const NET_FANOUT_BASE: Ps = Ps(420);
/// Extra net delay per log2 of fanout endpoints.
pub const NET_FANOUT_PER_LOG2: Ps = Ps(120);

/// Comparator-stage routing: class sums travel across the die between
/// class columns, the longest nets in the design (paper §II-A: comparison
/// "introduces significant overhead ... when using digital comparators").
pub const NET_CMP: Ps = Ps(1900);

/// Carry-chain delay per bit (CARRY4-class).
pub const CARRY_PER_BIT: Ps = Ps(15);

/// Congestion multiplier: Vivado's generic flow degrades as the design
/// fills the device — routing detours grow roughly with the log of design
/// size. `m = 1 + CONG_K * log2(luts / CONG_BASE)` clamped to ≥ 1.
pub const CONG_BASE: f64 = 500.0;
pub const CONG_K: f64 = 0.42;

/// Bundled-data margin on the asynchronous clause block (the bundling net
/// delay must exceed the worst-case clause delay, §IV-A).
pub const BUNDLE_MARGIN: f64 = 1.05;

/// Asynchronous controller overhead per inference (MOUSETRAP latch + XNOR
/// + wait/join fragments, Fig. 8).
pub const ASYNC_CTL: Ps = Ps(600);

/// Synchronous clocking overhead added to the critical path when deriving
/// the minimum clock period (setup + skew + jitter).
pub const SYNC_CLOCK_MARGIN: Ps = Ps(900);

/// FPT'18 ripple-chain per-bit delay (LUT-level chain, not CARRY4: the
/// original proposes architectural support; on stock fabric each chain
/// stage traverses a LUT + short net).
pub const FPT18_PER_BIT: Ps = Ps(460);

/// ASYNC'21 dual-rail completion-detection overhead per popcount stage.
pub const ASYNC21_PER_BIT: Ps = Ps(520);

/// Congestion multiplier for a design of `total_luts`.
pub fn congestion(total_luts: u32) -> f64 {
    let m = 1.0 + CONG_K * ((total_luts as f64 / CONG_BASE).max(1.0)).log2();
    m.max(1.0)
}

/// Depth of a LUT6 AND-reduction tree over `fanin` literals.
pub fn lut6_tree_depth(fanin: usize) -> u32 {
    if fanin <= 1 {
        return 1;
    }
    let mut depth = 0u32;
    let mut width = fanin;
    while width > 1 {
        width = width.div_ceil(6);
        depth += 1;
    }
    depth
}

/// LUT count of a LUT6 reduction tree over `fanin` inputs.
pub fn lut6_tree_luts(fanin: usize) -> u32 {
    if fanin <= 1 {
        return 1;
    }
    let mut total = 0u32;
    let mut width = fanin;
    while width > 1 {
        let level = width.div_ceil(6);
        total += level as u32;
        width = level;
    }
    total
}

/// Bit width of a signed class sum over `c` ±1 votes (sign + magnitude).
pub fn sum_width(c: usize) -> usize {
    (usize::BITS - c.max(1).leading_zeros()) as usize + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn congestion_monotone_and_floored() {
        assert_eq!(congestion(100), 1.0);
        assert!(congestion(2_000) > 1.0);
        assert!(congestion(20_000) > congestion(2_000));
    }

    #[test]
    fn tree_depth_examples() {
        assert_eq!(lut6_tree_depth(1), 1);
        assert_eq!(lut6_tree_depth(6), 1);
        assert_eq!(lut6_tree_depth(7), 2);
        assert_eq!(lut6_tree_depth(36), 2);
        assert_eq!(lut6_tree_depth(37), 3);
        assert_eq!(lut6_tree_depth(1568), 5);
    }

    #[test]
    fn tree_luts_examples() {
        assert_eq!(lut6_tree_luts(6), 1);
        assert_eq!(lut6_tree_luts(36), 7); // 6 + 1
        assert!(lut6_tree_luts(1568) > 1568 / 6);
    }

    #[test]
    fn sum_width_examples() {
        assert_eq!(sum_width(10), 5); // ±10 fits in 5 bits signed
        assert_eq!(sum_width(50), 7);
        assert_eq!(sum_width(100), 8);
    }
}
