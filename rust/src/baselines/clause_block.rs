//! Shared clause-block model.
//!
//! Every architecture (the paper's baselines and the proposed design)
//! computes the same propositional clause logic: per clause, an AND
//! reduction over its included literals, implemented as a LUT6 tree. The
//! first level also absorbs the feature-distribution fanout: each Boolean
//! input drives every clause that includes it, the highest-fanout nets in
//! the design.

use crate::util::Ps;

use super::{calib, DesignParams};

/// Critical-path delay of the clause stage under congestion factor `m`.
pub fn clause_delay(d: &DesignParams, m: f64) -> Ps {
    let depth = calib::lut6_tree_depth(d.max_clause_fanin);
    // Fanout of one feature: every clause of every class may tap it.
    let fanout = (d.c_total()).max(2) as f64;
    let first_level = calib::LUT_D
        + calib::NET_FANOUT_BASE
        + calib::NET_FANOUT_PER_LOG2.scale(fanout.log2());
    let deeper = calib::LUT_D + calib::NET_LOCAL;
    Ps(first_level.0 + deeper.0 * (depth.saturating_sub(1)) as u64).scale(m)
}

/// LUT count of all clause blocks (uses the average trained fan-in).
pub fn clause_luts(d: &DesignParams) -> u32 {
    let per_clause = calib::lut6_tree_luts(d.avg_clause_fanin.round().max(1.0) as usize);
    per_clause * d.c_total() as u32
}

/// Expected clause-logic toggles per inference at input activity α:
/// a fraction of clause-tree LUTs re-evaluate when inputs change.
pub fn clause_toggles(d: &DesignParams, activity: f64) -> f64 {
    clause_luts(d) as f64 * activity
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delay_grows_with_fanin_and_congestion() {
        let small = DesignParams::synthetic(3, 10, 12);
        let large = DesignParams::synthetic(10, 100, 784);
        assert!(clause_delay(&large, 1.0) > clause_delay(&small, 1.0));
        assert!(clause_delay(&small, 2.0) > clause_delay(&small, 1.0));
    }

    #[test]
    fn luts_scale_with_clauses() {
        let a = DesignParams::synthetic(6, 50, 200);
        let b = DesignParams::synthetic(6, 100, 200);
        assert!((clause_luts(&b) as f64 / clause_luts(&a) as f64 - 2.0).abs() < 0.01);
    }

    #[test]
    fn toggles_linear_in_activity() {
        let d = DesignParams::synthetic(6, 100, 200);
        assert!((clause_toggles(&d, 0.5) / clause_toggles(&d, 0.1) - 5.0).abs() < 1e-9);
    }
}
