//! Baseline popcount/comparison architectures (paper §IV-B):
//!
//! * [`GenericAdder`] — the paper's "Generic implementation": synchronous
//!   TM with a Vivado-style compressor/adder-tree popcount and a sequential
//!   argmax comparator. Latency = minimum clock period = worst-case
//!   critical path (clause → popcount → compare).
//! * [`Fpt18`] — Kim et al. (FPT'18 [6]): ripple-carry-like popcount,
//!   linear critical path in the input width, fewer LUTs.
//! * [`Async21`] — Wheeldon et al. (ASYNC'21 [24]): dual-rail self-timed
//!   8-bit popcounters; the paper compares resource utilization only
//!   (equivalent LUT count), which we model, plus a latency estimate for
//!   the scaling sweeps.
//! * The proposed time-domain design lives in [`crate::asynctm`]; its
//!   resource/power inventory is exposed here through the same
//!   [`Architecture`] interface so every experiment iterates one list.
//!
//! Every architecture reports a [`LatencyBreakdown`], [`ResourceBreakdown`]
//! and [`ToggleInventory`] (consumed by [`crate::power`]), decomposed into
//! clause / popcount / compare / control — the decomposition behind the
//! paper's "popcount and comparison are the bottleneck" claim (Fig. 9's
//! shaded shares).

pub mod adder_tree;
pub mod async21;
pub mod calib;
pub mod clause_block;
pub mod comparator;
pub mod fpt18;

pub use adder_tree::GenericAdder;
pub use async21::Async21;
pub use fpt18::Fpt18;

use crate::util::Ps;

/// Workload/design parameters shared by all architectures.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DesignParams {
    pub n_classes: usize,
    pub clauses_per_class: usize,
    /// Boolean input features (literals = 2 × features).
    pub n_features: usize,
    /// Largest clause fan-in (trained models are sparse; sweeps use an
    /// assumed density).
    pub max_clause_fanin: usize,
    /// Average clause fan-in, for resource estimates.
    pub avg_clause_fanin: f64,
}

impl DesignParams {
    /// From a trained model.
    pub fn from_model(m: &crate::tm::TmModel) -> DesignParams {
        let total_inc: usize = m
            .include
            .iter()
            .map(|row| row.iter().filter(|&&b| b).count())
            .sum();
        DesignParams {
            n_classes: m.n_classes,
            clauses_per_class: m.clauses_per_class,
            n_features: m.n_features,
            max_clause_fanin: m.max_clause_fanin().max(1),
            avg_clause_fanin: (total_inc as f64 / m.c_total() as f64).max(1.0),
        }
    }

    /// For scaling sweeps: assume clauses include ~8 % of literals (typical
    /// of trained TMs), at least 4.
    pub fn synthetic(n_classes: usize, clauses_per_class: usize, n_features: usize) -> Self {
        let fanin = ((2 * n_features) as f64 * 0.08).max(4.0);
        DesignParams {
            n_classes,
            clauses_per_class,
            n_features,
            max_clause_fanin: (fanin * 1.6) as usize,
            avg_clause_fanin: fanin,
        }
    }

    pub fn c_total(&self) -> usize {
        self.n_classes * self.clauses_per_class
    }

    pub fn sum_width(&self) -> usize {
        calib::sum_width(self.clauses_per_class)
    }
}

/// Per-stage latency decomposition (the shares shaded in Fig. 9a).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LatencyBreakdown {
    pub clause: Ps,
    pub popcount: Ps,
    pub compare: Ps,
    pub control: Ps,
}

impl LatencyBreakdown {
    pub fn total(&self) -> Ps {
        self.clause + self.popcount + self.compare + self.control
    }

    /// Fraction contributed by popcount + comparison (the bottleneck claim).
    pub fn popcount_compare_share(&self) -> f64 {
        let t = self.total().as_ps_f64();
        if t == 0.0 {
            return 0.0;
        }
        (self.popcount + self.compare).as_ps_f64() / t
    }
}

/// Per-stage LUT/FF decomposition (Fig. 9b / Fig. 11).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ResourceBreakdown {
    pub clause_luts: u32,
    pub popcount_luts: u32,
    pub compare_luts: u32,
    pub control_luts: u32,
    pub ffs: u32,
}

impl ResourceBreakdown {
    pub fn luts(&self) -> u32 {
        self.clause_luts + self.popcount_luts + self.compare_luts + self.control_luts
    }

    /// The paper's Fig. 9b metric: LUTs and FFs weighted equally.
    pub fn total(&self) -> u32 {
        self.luts() + self.ffs
    }

    pub fn popcount_compare_share(&self) -> f64 {
        if self.total() == 0 {
            return 0.0;
        }
        (self.popcount_luts + self.compare_luts) as f64 / self.total() as f64
    }
}

/// Switching inventory for the power model ([`crate::power`]).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ToggleInventory {
    /// Expected LUT output toggles per inference in clause logic
    /// (∝ input activity).
    pub clause_toggles_per_inference: f64,
    /// Popcount-stage toggles per inference (adder trees glitch: several
    /// transitions per LUT per cycle).
    pub popcount_toggles_per_inference: f64,
    /// Comparator toggles per inference.
    pub compare_toggles_per_inference: f64,
    /// FFs loaded by the clock every cycle (zero for async designs).
    pub clocked_ffs: u32,
    /// Latch/control toggles per inference (async handshake cells).
    pub control_toggles_per_inference: f64,
}

/// Common interface every architecture implements; experiments iterate a
/// `Vec<Box<dyn Architecture>>`.
pub trait Architecture {
    fn name(&self) -> &'static str;

    /// Worst-case (synchronous: the minimum clock period; asynchronous:
    /// all-high-latency) inference latency.
    fn latency(&self, d: &DesignParams) -> LatencyBreakdown;

    fn resources(&self, d: &DesignParams) -> ResourceBreakdown;

    /// Switching inventory at the given input activity factor α.
    fn toggles(&self, d: &DesignParams, activity: f64) -> ToggleInventory;

    /// Whether `latency` is a clock period (true) or a self-timed
    /// per-inference latency (false).
    fn is_synchronous(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn design_params_from_synthetic() {
        let d = DesignParams::synthetic(10, 100, 784);
        assert_eq!(d.c_total(), 1000);
        assert_eq!(d.sum_width(), 8);
        assert!(d.avg_clause_fanin > 4.0);
        assert!(d.max_clause_fanin > d.avg_clause_fanin as usize);
    }

    #[test]
    fn latency_breakdown_share() {
        let lb = LatencyBreakdown {
            clause: Ps(1000),
            popcount: Ps(2000),
            compare: Ps(6000),
            control: Ps(1000),
        };
        assert_eq!(lb.total(), Ps(10_000));
        assert!((lb.popcount_compare_share() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn resource_breakdown_totals() {
        let rb = ResourceBreakdown {
            clause_luts: 100,
            popcount_luts: 50,
            compare_luts: 30,
            control_luts: 20,
            ffs: 40,
        };
        assert_eq!(rb.luts(), 200);
        assert_eq!(rb.total(), 240);
        assert!((rb.popcount_compare_share() - 80.0 / 240.0).abs() < 1e-12);
    }
}
