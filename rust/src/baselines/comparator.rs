//! Sequential argmax comparator (the adder-based designs' comparison
//! stage; paper §II-A, Fig. 10b).
//!
//! Class sums are compared pairwise down a chain: (K−1) comparator stages,
//! each a signed w-bit magnitude compare (carry chain) plus the mux that
//! forwards the running maximum and its index. Latency is linear in the
//! class count — the scaling the paper contrasts with the arbiter tree's
//! near-constant response — and the sum nets are the longest in the design
//! (class columns sit apart on the die), which [`calib::NET_CMP`] models.

use crate::util::Ps;

use super::{calib, DesignParams};

/// Critical-path delay of the sequential argmax over K class sums.
pub fn compare_delay(d: &DesignParams, m: f64) -> Ps {
    if d.n_classes <= 1 {
        return Ps::ZERO;
    }
    let w = d.sum_width() as u64;
    let stage = calib::LUT_D + calib::NET_CMP + Ps(calib::CARRY_PER_BIT.0 * w);
    stage.scale(m) * (d.n_classes as u64 - 1)
}

/// LUTs of the comparator chain: per stage, w LUTs compare + w LUTs of
/// max-mux + index bookkeeping.
pub fn compare_luts(d: &DesignParams) -> u32 {
    if d.n_classes <= 1 {
        return 0;
    }
    let w = d.sum_width() as u32;
    let idx = (usize::BITS - d.n_classes.leading_zeros()) as u32;
    (d.n_classes as u32 - 1) * (2 * w + idx)
}

/// Comparator toggles per inference: sums change every inference, so the
/// chain re-evaluates fully, with adder-style glitching on the ripples.
pub fn compare_toggles(d: &DesignParams, glitch: f64) -> f64 {
    compare_luts(d) as f64 * glitch
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_linear_in_classes() {
        let d6 = DesignParams::synthetic(6, 100, 200);
        let d12 = DesignParams::synthetic(12, 100, 200);
        let t6 = compare_delay(&d6, 1.0).as_ps_f64();
        let t12 = compare_delay(&d12, 1.0).as_ps_f64();
        assert!(((t12 / t6) - 11.0 / 5.0).abs() < 0.02, "(K−1)-linear");
    }

    #[test]
    fn single_class_free() {
        let d = DesignParams::synthetic(1, 100, 200);
        assert_eq!(compare_delay(&d, 1.0), Ps::ZERO);
        assert_eq!(compare_luts(&d), 0);
    }

    #[test]
    fn luts_grow_with_sum_width() {
        let narrow = DesignParams::synthetic(6, 10, 200);
        let wide = DesignParams::synthetic(6, 500, 200);
        assert!(compare_luts(&wide) > compare_luts(&narrow));
    }
}
