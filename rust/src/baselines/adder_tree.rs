//! "Generic implementation" baseline: synchronous TM with compressor/adder
//! tree popcount and sequential argmax (paper §IV-B, implemented with
//! Vivado 2024.1's generic process).
//!
//! Popcount structure per class: the C/2 positive and C/2 negative clause
//! outputs are each compressed 6→3 by LUT6 compressors ([10]-style), then
//! summed down a binary adder tree (log2 depth, carry-chain adders), and
//! finally subtracted to a signed class sum. The minimum clock period is
//! the full combinational cone: clause → popcount → compare (+ clocking
//! margin), which is what the paper reports as latency for the synchronous
//! designs.

use crate::util::Ps;

use super::{
    calib, clause_block, comparator, Architecture, DesignParams, LatencyBreakdown,
    ResourceBreakdown, ToggleInventory,
};

/// Glitch multiplier of a combinational adder tree: ripple/compressor
/// stages transition several times per evaluation before settling
/// (the well-known adder-tree glitching the paper's Fig. 12 exposes at
/// high activity).
pub const ADDER_GLITCH: f64 = 2.5;

#[derive(Debug, Clone, Copy, Default)]
pub struct GenericAdder;

impl GenericAdder {
    /// Adder-tree levels over `n` one-bit inputs: one compressor level then
    /// a binary tree over the compressor outputs.
    fn tree_levels(n: usize) -> u32 {
        if n <= 1 {
            return 1;
        }
        let groups = n.div_ceil(6).max(1);
        1 + (usize::BITS - (groups.max(1)).leading_zeros()) as u32
    }

    /// Popcount critical path for one class (both polarities in parallel,
    /// then the subtractor level) — the worst case, i.e. every carry chain
    /// rippling through the full sum width.
    pub fn popcount_delay(d: &DesignParams, m: f64) -> Ps {
        Self::popcount_settle(d, m, d.sum_width())
    }

    /// Combinational settle time of the popcount stage when the widest
    /// actual class sum occupies only `w` bits (`w ≤ sum_width`): carry
    /// chains stop rippling at the top active bit, so small sums settle
    /// earlier than the worst case. This is the per-request latency model
    /// the executable engine ([`crate::hw::SyncReplayEngine`]) evaluates.
    pub fn popcount_settle(d: &DesignParams, m: f64, w: usize) -> Ps {
        let half = (d.clauses_per_class / 2).max(1);
        let levels = Self::tree_levels(half) as u64;
        let w = w.clamp(1, d.sum_width()) as u64;
        let level_delay = calib::LUT_D + calib::NET_LOCAL + Ps(calib::CARRY_PER_BIT.0 * w / 2);
        let subtract = calib::LUT_D + calib::NET_LOCAL + Ps(calib::CARRY_PER_BIT.0 * w);
        Ps(level_delay.0 * levels + subtract.0).scale(m)
    }

    /// Popcount LUTs for all classes.
    pub fn popcount_luts(d: &DesignParams) -> u32 {
        let half = (d.clauses_per_class / 2).max(1);
        let w = calib::sum_width(d.clauses_per_class) as u32;
        // Per polarity: 3 LUTs per 6-bit compressor group + tree adders.
        let compress = half.div_ceil(6) as u32 * 3;
        let adders = (half.div_ceil(6).saturating_sub(1)) as u32 * w;
        let per_class = 2 * (compress + adders) + w; // + subtractor
        per_class * d.n_classes as u32
    }

    fn ffs(d: &DesignParams) -> u32 {
        // Input feature regs + registered clause outputs + sum regs + ctl.
        (d.n_features + d.c_total() + d.n_classes * d.sum_width() + 4) as u32
    }
}

impl Architecture for GenericAdder {
    fn name(&self) -> &'static str {
        "generic"
    }

    fn latency(&self, d: &DesignParams) -> LatencyBreakdown {
        let m = calib::congestion(self.resources(d).luts());
        LatencyBreakdown {
            clause: clause_block::clause_delay(d, m),
            popcount: Self::popcount_delay(d, m),
            compare: comparator::compare_delay(d, m),
            control: calib::SYNC_CLOCK_MARGIN,
        }
    }

    fn resources(&self, d: &DesignParams) -> ResourceBreakdown {
        ResourceBreakdown {
            clause_luts: clause_block::clause_luts(d),
            popcount_luts: Self::popcount_luts(d),
            compare_luts: comparator::compare_luts(d),
            control_luts: 8,
            ffs: Self::ffs(d),
        }
    }

    fn toggles(&self, d: &DesignParams, activity: f64) -> ToggleInventory {
        ToggleInventory {
            clause_toggles_per_inference: clause_block::clause_toggles(d, activity),
            // Adder tree re-evaluates when its inputs (clause outputs)
            // change; glitching multiplies the transitions.
            popcount_toggles_per_inference: Self::popcount_luts(d) as f64
                * activity
                * ADDER_GLITCH,
            compare_toggles_per_inference: comparator::compare_toggles(d, ADDER_GLITCH)
                * activity.max(0.25),
            clocked_ffs: Self::ffs(d),
            control_toggles_per_inference: 4.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn popcount_latency_is_logarithmic() {
        // Fig. 10a: doubling clauses adds ~one tree level, not 2×.
        let m = 1.0;
        let t100 = GenericAdder::popcount_delay(&DesignParams::synthetic(6, 100, 200), m);
        let t200 = GenericAdder::popcount_delay(&DesignParams::synthetic(6, 200, 200), m);
        let t400 = GenericAdder::popcount_delay(&DesignParams::synthetic(6, 400, 200), m);
        let d1 = t200.saturating_sub(t100);
        let d2 = t400.saturating_sub(t200);
        assert!(t200 < t100.scale(1.45), "log-ish growth, not linear");
        assert!(d2 <= d1.scale(1.6), "increments roughly constant per doubling");
    }

    #[test]
    fn min_clock_period_includes_all_stages() {
        let d = DesignParams::synthetic(10, 50, 784);
        let lb = GenericAdder.latency(&d);
        assert!(lb.clause > Ps::ZERO);
        assert!(lb.popcount > Ps::ZERO);
        assert!(lb.compare > lb.popcount, "comparison dominates at 10 classes");
        assert_eq!(lb.control, calib::SYNC_CLOCK_MARGIN);
    }

    #[test]
    fn resources_scale_linearly_with_clauses() {
        let a = GenericAdder.resources(&DesignParams::synthetic(6, 100, 200));
        let b = GenericAdder.resources(&DesignParams::synthetic(6, 200, 200));
        let ratio = b.total() as f64 / a.total() as f64;
        assert!((1.7..2.3).contains(&ratio), "≈2× at 2× clauses, got {ratio}");
    }

    #[test]
    fn toggles_scale_with_activity() {
        let d = DesignParams::synthetic(6, 100, 200);
        let lo = GenericAdder.toggles(&d, 0.1);
        let hi = GenericAdder.toggles(&d, 0.5);
        assert!(hi.popcount_toggles_per_inference > 4.0 * lo.popcount_toggles_per_inference);
        assert_eq!(lo.clocked_ffs, hi.clocked_ffs, "clock load is activity-independent");
    }
}
