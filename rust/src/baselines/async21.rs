//! ASYNC'21 baseline (Wheeldon et al. [24]): dual-rail self-timed TM with
//! 8-bit popcounters ([9]).
//!
//! Dual-rail encoding carries each logical bit on two wires with a spacer
//! phase, giving input-completion detection "for free" but roughly
//! doubling-to-tripling the combinational logic. The paper compares
//! *resource utilization only* (equivalent LUT count of the popcounters,
//! synthesized in Vivado) because the circuit is not FPGA-native; we model
//! resources the same way and additionally provide a latency/power
//! estimate so the scaling sweeps can include it.

use crate::util::Ps;

use super::{
    calib, clause_block, comparator, Architecture, DesignParams, LatencyBreakdown,
    ResourceBreakdown, ToggleInventory,
};

/// Dual-rail LUT inflation over single-rail adder logic.
const DUAL_RAIL_FACTOR: f64 = 2.4;
/// Completion-detection LUTs per clause bit.
const COMPLETION_PER_BIT: f64 = 0.35;

#[derive(Debug, Clone, Copy, Default)]
pub struct Async21;

impl Async21 {
    pub fn popcount_luts(d: &DesignParams) -> u32 {
        let single_rail = super::adder_tree::GenericAdder::popcount_luts(d) as f64;
        let completion = d.c_total() as f64 * COMPLETION_PER_BIT;
        (single_rail * DUAL_RAIL_FACTOR + completion).ceil() as u32
    }

    /// Self-timed ripple through the 8-bit popcounter cascade:
    /// data-dependent, average-case linear in the clause count.
    pub fn popcount_delay(d: &DesignParams, m: f64) -> Ps {
        let n = d.clauses_per_class.max(1) as u64;
        Ps(calib::ASYNC21_PER_BIT.0 * n).scale(m)
    }

    fn ffs(d: &DesignParams) -> u32 {
        // Dual-rail handshake latches on clause outputs + feature latches.
        (d.n_features + d.c_total() + 8) as u32
    }
}

impl Architecture for Async21 {
    fn name(&self) -> &'static str {
        "async21"
    }

    fn latency(&self, d: &DesignParams) -> LatencyBreakdown {
        let m = calib::congestion(self.resources(d).luts());
        LatencyBreakdown {
            clause: clause_block::clause_delay(d, m),
            popcount: Self::popcount_delay(d, m),
            compare: comparator::compare_delay(d, m),
            control: calib::ASYNC_CTL,
        }
    }

    fn resources(&self, d: &DesignParams) -> ResourceBreakdown {
        ResourceBreakdown {
            clause_luts: clause_block::clause_luts(d),
            popcount_luts: Self::popcount_luts(d),
            compare_luts: comparator::compare_luts(d),
            control_luts: 24,
            ffs: Self::ffs(d),
        }
    }

    fn toggles(&self, d: &DesignParams, activity: f64) -> ToggleInventory {
        ToggleInventory {
            clause_toggles_per_inference: clause_block::clause_toggles(d, activity),
            // Dual-rail: every bit transitions twice per cycle (data +
            // spacer) regardless of data — activity-independent, like the
            // paper notes for return-to-zero protocols.
            popcount_toggles_per_inference: Self::popcount_luts(d) as f64 * 2.0,
            compare_toggles_per_inference: comparator::compare_toggles(d, 1.0),
            clocked_ffs: 0,
            control_toggles_per_inference: d.c_total() as f64 * 0.5,
        }
    }

    fn is_synchronous(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::adder_tree::GenericAdder;

    #[test]
    fn heaviest_popcount_resources() {
        // Paper Fig. 9b: ASYNC'21's dual-rail popcount dominates resource
        // cost versus every other implementation.
        let d = DesignParams::synthetic(10, 50, 784);
        assert!(Async21::popcount_luts(&d) > 2 * GenericAdder::popcount_luts(&d));
    }

    #[test]
    fn no_clock_load() {
        let d = DesignParams::synthetic(10, 50, 784);
        assert_eq!(Async21.toggles(&d, 0.3).clocked_ffs, 0);
    }

    #[test]
    fn popcount_toggles_activity_independent() {
        let d = DesignParams::synthetic(6, 100, 200);
        let lo = Async21.toggles(&d, 0.1);
        let hi = Async21.toggles(&d, 0.5);
        assert_eq!(
            lo.popcount_toggles_per_inference,
            hi.popcount_toggles_per_inference
        );
    }
}
