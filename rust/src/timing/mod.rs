//! Event-driven gate-level timing simulator.
//!
//! The experiment sweeps use fast behavioral models ([`crate::pdl`],
//! [`crate::arbiter`], [`crate::asynctm`]); this simulator is the ground
//! truth they are validated against (see `rust/tests/timing_equivalence.rs`
//! and the module tests here): a picosecond-resolution, deterministic
//! discrete-event simulator over gate netlists, in the style of a tiny
//! gate-level VCS.
//!
//! * Nets carry boolean levels; transitions are events on a time-ordered
//!   queue (ties broken by sequence number ⇒ fully deterministic).
//! * Components are gates with a propagation delay and an inertial filter:
//!   a gate re-evaluates when an input changes and schedules its output
//!   `delay` later; a pending opposite-polarity schedule is replaced
//!   (classic inertial-delay cancellation).
//! * The SR-latch arbiter is a primitive (not two cross-coupled NANDs):
//!   cross-coupled zero-margin feedback would oscillate in a pure-delay
//!   model, and its analog metastability behaviour is exactly what
//!   [`crate::arbiter::Arbiter2`] parameterizes.

pub mod circuit;
pub mod sim;

pub use circuit::{Circuit, GateKind, NetId};
pub use sim::{SimStats, Simulator};
