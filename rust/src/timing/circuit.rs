//! Netlist representation for the event-driven simulator.

use crate::util::Ps;

/// A net (wire) in the circuit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NetId(pub u32);

/// Gate primitives. `Mux2`'s input order is (sel, a, b): output = sel ? b : a
/// — matching the PDL delay element (sel = clause bit, a = high-latency
/// arc, b = low-latency arc for positive polarity).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GateKind {
    Buf,
    Inv,
    And2,
    Or2,
    Nand2,
    Nor2,
    Xor2,
    Xnor2,
    Mux2,
    /// Transparent latch: inputs (enable, d); transparent while enable=1.
    LatchT,
}

impl GateKind {
    pub fn arity(self) -> usize {
        match self {
            GateKind::Buf | GateKind::Inv => 1,
            GateKind::Mux2 | GateKind::LatchT => match self {
                GateKind::LatchT => 2,
                _ => 3,
            },
            _ => 2,
        }
    }

    /// Combinational evaluation. For `LatchT`, `current` is the retained
    /// output value used while opaque.
    pub fn eval(self, inputs: &[bool], current: bool) -> bool {
        match self {
            GateKind::Buf => inputs[0],
            GateKind::Inv => !inputs[0],
            GateKind::And2 => inputs[0] && inputs[1],
            GateKind::Or2 => inputs[0] || inputs[1],
            GateKind::Nand2 => !(inputs[0] && inputs[1]),
            GateKind::Nor2 => !(inputs[0] || inputs[1]),
            GateKind::Xor2 => inputs[0] ^ inputs[1],
            GateKind::Xnor2 => !(inputs[0] ^ inputs[1]),
            GateKind::Mux2 => {
                if inputs[0] {
                    inputs[2]
                } else {
                    inputs[1]
                }
            }
            GateKind::LatchT => {
                if inputs[0] {
                    inputs[1]
                } else {
                    current
                }
            }
        }
    }
}

/// One gate instance.
#[derive(Debug, Clone)]
pub struct Gate {
    pub kind: GateKind,
    pub inputs: Vec<NetId>,
    pub output: NetId,
    pub delay: Ps,
}

/// A gate netlist under construction.
#[derive(Debug, Default, Clone)]
pub struct Circuit {
    pub(crate) n_nets: u32,
    pub(crate) gates: Vec<Gate>,
    /// Initial level per net (defaults false).
    pub(crate) initial: Vec<bool>,
}

impl Circuit {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate a fresh net (initial level 0).
    pub fn net(&mut self) -> NetId {
        let id = NetId(self.n_nets);
        self.n_nets += 1;
        self.initial.push(false);
        id
    }

    /// Allocate a net with a defined initial level.
    pub fn net_init(&mut self, level: bool) -> NetId {
        let id = self.net();
        self.initial[id.0 as usize] = level;
        id
    }

    pub fn n_nets(&self) -> u32 {
        self.n_nets
    }

    pub fn n_gates(&self) -> usize {
        self.gates.len()
    }

    /// Add a gate; returns its output net.
    pub fn gate(&mut self, kind: GateKind, inputs: &[NetId], delay: Ps) -> NetId {
        assert_eq!(inputs.len(), kind.arity(), "{kind:?} arity mismatch");
        let output = self.net();
        self.gates.push(Gate { kind, inputs: inputs.to_vec(), output, delay });
        output
    }

    /// Add a gate driving an existing net (for feedback structures).
    pub fn gate_onto(&mut self, kind: GateKind, inputs: &[NetId], output: NetId, delay: Ps) {
        assert_eq!(inputs.len(), kind.arity(), "{kind:?} arity mismatch");
        self.gates.push(Gate { kind, inputs: inputs.to_vec(), output, delay });
    }

    /// Convenience: a buffer used purely as a routed-net delay.
    pub fn delay_net(&mut self, from: NetId, delay: Ps) -> NetId {
        self.gate(GateKind::Buf, &[from], delay)
    }

    /// Build one PDL delay element: `prev` fans into a slow arc and a fast
    /// arc; `sel` chooses (sel=1 → fast for positive polarity; the caller
    /// swaps arcs for negative polarity). Returns the element output.
    pub fn pdl_element(&mut self, prev: NetId, sel: NetId, lo: Ps, hi: Ps, lut_delay: Ps) -> NetId {
        let slow = self.delay_net(prev, hi.saturating_sub(lut_delay));
        let fast = self.delay_net(prev, lo.saturating_sub(lut_delay));
        self.gate(GateKind::Mux2, &[sel, slow, fast], lut_delay)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_eval_truth_tables() {
        use GateKind::*;
        assert!(!Nand2.eval(&[true, true], false));
        assert!(Nand2.eval(&[true, false], false));
        assert!(Nor2.eval(&[false, false], false));
        assert!(!Nor2.eval(&[true, false], false));
        assert!(Xor2.eval(&[true, false], false));
        assert!(Xnor2.eval(&[true, true], false));
        assert!(Mux2.eval(&[false, true, false], false)); // sel=0 → a
        assert!(Mux2.eval(&[true, false, true], false)); // sel=1 → b
        assert!(LatchT.eval(&[true, true], false)); // transparent
        assert!(LatchT.eval(&[false, true], false) == false); // opaque holds
    }

    #[test]
    fn circuit_building() {
        let mut c = Circuit::new();
        let a = c.net();
        let b = c.net_init(true);
        let o = c.gate(GateKind::And2, &[a, b], Ps(100));
        assert_eq!(c.n_gates(), 1);
        assert_eq!(c.n_nets(), 3);
        assert_ne!(o, a);
    }

    #[test]
    #[should_panic(expected = "arity mismatch")]
    fn arity_checked() {
        let mut c = Circuit::new();
        let a = c.net();
        c.gate(GateKind::And2, &[a], Ps(1));
    }
}
