//! The discrete-event engine.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::util::Ps;

use super::circuit::{Circuit, NetId};

/// One scheduled transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Event {
    at: Ps,
    seq: u64,
    net: NetId,
    level: bool,
    /// Gate whose pending slot owns this event (None for external drives).
    gate: Option<u32>,
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Simulation statistics (perf instrumentation for §Perf).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SimStats {
    pub events_processed: u64,
    pub events_scheduled: u64,
    pub events_cancelled: u64,
    /// Cancelled seqs reclaimed from the lazy-deletion set when their
    /// event was popped. Once the queue drains this equals
    /// `events_cancelled` — the invariant that keeps the set from growing
    /// for the life of the simulator.
    pub cancelled_reclaimed: u64,
}

/// The simulator: owns net state and the event queue.
pub struct Simulator {
    levels: Vec<bool>,
    /// gates indexed densely; per-net fanout lists (gate indices).
    gates: Vec<super::circuit::Gate>,
    fanout: Vec<Vec<u32>>,
    /// Pending inertial schedule per gate: (event seq, level) if any.
    pending: Vec<Option<(u64, bool)>>,
    queue: BinaryHeap<Reverse<Event>>,
    /// Cancelled event seqs (lazy deletion).
    cancelled: std::collections::HashSet<u64>,
    next_seq: u64,
    now: Ps,
    /// Transition traces for watched nets.
    watched: Vec<Option<Vec<(Ps, bool)>>>,
    pub stats: SimStats,
}

impl Simulator {
    pub fn new(circuit: &Circuit) -> Self {
        let n = circuit.n_nets() as usize;
        let mut fanout = vec![Vec::new(); n];
        for (gi, g) in circuit.gates.iter().enumerate() {
            for inp in &g.inputs {
                fanout[inp.0 as usize].push(gi as u32);
            }
        }
        Self {
            levels: circuit.initial.clone(),
            gates: circuit.gates.clone(),
            fanout,
            pending: vec![None; circuit.gates.len()],
            queue: BinaryHeap::new(),
            cancelled: std::collections::HashSet::new(),
            next_seq: 0,
            now: Ps::ZERO,
            watched: vec![None; n],
            stats: SimStats::default(),
        }
    }

    pub fn now(&self) -> Ps {
        self.now
    }

    pub fn level(&self, net: NetId) -> bool {
        self.levels[net.0 as usize]
    }

    /// Record all transitions on `net` (retrievable via [`Self::trace`]).
    pub fn watch(&mut self, net: NetId) {
        self.watched[net.0 as usize] = Some(Vec::new());
    }

    pub fn trace(&self, net: NetId) -> &[(Ps, bool)] {
        self.watched[net.0 as usize]
            .as_deref()
            .expect("net not watched")
    }

    /// Time of the first transition to `level` on a watched net.
    pub fn first_edge(&self, net: NetId, level: bool) -> Option<Ps> {
        self.trace(net).iter().find(|&&(_, l)| l == level).map(|&(t, _)| t)
    }

    /// Cancelled seqs still awaiting lazy reclamation (drains to zero once
    /// the queue drains — asserted by the test suite).
    pub fn outstanding_cancellations(&self) -> usize {
        self.cancelled.len()
    }

    /// Externally drive a net at an absolute time.
    pub fn schedule(&mut self, net: NetId, level: bool, at: Ps) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.stats.events_scheduled += 1;
        self.queue.push(Reverse(Event { at, seq, net, level, gate: None }));
    }

    /// Run until the queue drains or `t_max` passes; returns events processed.
    pub fn run_until(&mut self, t_max: Ps) -> u64 {
        let start_events = self.stats.events_processed;
        while let Some(Reverse(ev)) = self.queue.peek().copied() {
            if ev.at > t_max {
                break;
            }
            self.queue.pop();
            // The event is leaving the queue: release its gate's pending
            // slot *now*, so a later evaluation can never cancel a seq
            // that is no longer queued (such a seq would sit in
            // `cancelled` for the life of the simulator).
            if let Some(gi) = ev.gate {
                if matches!(self.pending[gi as usize], Some((seq, _)) if seq == ev.seq) {
                    self.pending[gi as usize] = None;
                }
            }
            // Lazy-deletion check; skip the hash probe entirely when no
            // cancellations are outstanding (the common case, §Perf).
            if !self.cancelled.is_empty() && self.cancelled.remove(&ev.seq) {
                self.stats.cancelled_reclaimed += 1;
                continue;
            }
            self.now = ev.at;
            let idx = ev.net.0 as usize;
            if self.levels[idx] == ev.level {
                continue; // no actual transition
            }
            self.levels[idx] = ev.level;
            self.stats.events_processed += 1;
            if let Some(trace) = &mut self.watched[idx] {
                trace.push((ev.at, ev.level));
            }
            // Re-evaluate fanout gates (indexed loop: the fanout lists are
            // immutable after construction, and cloning here would allocate
            // on every event — the simulator's hottest line, §Perf).
            let n_fan = self.fanout[idx].len();
            for fi in 0..n_fan {
                let gi = self.fanout[idx][fi] as usize;
                self.eval_gate(gi);
            }
        }
        self.stats.events_processed - start_events
    }

    fn eval_gate(&mut self, gi: usize) {
        let g = &self.gates[gi];
        let inputs: Vec<bool> = g.inputs.iter().map(|n| self.levels[n.0 as usize]).collect();
        let current = self.levels[g.output.0 as usize];
        let new_level = g.kind.eval(&inputs, current);

        // Inertial-delay model: at most one pending schedule per gate. The
        // slot is cleared eagerly when its event pops in `run_until`, so
        // an occupied slot always names a *queued* event: cancelling it
        // really removes work, and the cancelled seq is guaranteed to be
        // reclaimed when that event is popped and skipped.
        match self.pending[gi] {
            Some((seq, lvl)) if lvl == new_level => {
                let _ = seq; // already scheduled to the right level
                return;
            }
            Some((seq, _)) => {
                // Cancel the stale opposite schedule (pulse swallowed).
                self.cancelled.insert(seq);
                self.stats.events_cancelled += 1;
                self.pending[gi] = None;
            }
            None => {}
        }
        if new_level == current {
            return;
        }
        let at = self.now + self.gates[gi].delay;
        let seq = self.next_seq;
        self.next_seq += 1;
        self.stats.events_scheduled += 1;
        self.pending[gi] = Some((seq, new_level));
        let out = self.gates[gi].output;
        self.queue
            .push(Reverse(Event { at, seq, net: out, level: new_level, gate: Some(gi as u32) }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timing::circuit::GateKind;

    #[test]
    fn buffer_chain_accumulates_delay() {
        let mut c = Circuit::new();
        let a = c.net();
        let mut n = a;
        for _ in 0..10 {
            n = c.delay_net(n, Ps(100));
        }
        let mut sim = Simulator::new(&c);
        sim.watch(n);
        sim.schedule(a, true, Ps(50));
        sim.run_until(Ps(1_000_000));
        assert_eq!(sim.first_edge(n, true), Some(Ps(1050)));
    }

    #[test]
    fn inertial_filter_swallows_short_pulse() {
        let mut c = Circuit::new();
        let a = c.net();
        let o = c.gate(GateKind::Buf, &[a], Ps(200));
        let mut sim = Simulator::new(&c);
        sim.watch(o);
        // 50 ps pulse through a 200 ps gate: swallowed.
        sim.schedule(a, true, Ps(100));
        sim.schedule(a, false, Ps(150));
        sim.run_until(Ps(10_000));
        assert!(sim.trace(o).is_empty(), "pulse shorter than delay must vanish");
        assert!(sim.stats.events_cancelled >= 1);
        // Lazy-deletion bookkeeping drains with the queue.
        assert_eq!(sim.stats.cancelled_reclaimed, sim.stats.events_cancelled);
        assert_eq!(sim.outstanding_cancellations(), 0);
    }

    #[test]
    fn cancelled_set_drains_under_sustained_glitching() {
        // A chain of slow gates fed with many sub-delay pulses produces a
        // steady stream of inertial cancellations. Every cancelled seq
        // must be reclaimed when its event pops — the set may not grow for
        // the life of the simulator (it previously leaked seqs whenever a
        // stale pending slot was cancelled after its event had fired).
        let mut c = Circuit::new();
        let a = c.net();
        let mut n = a;
        for _ in 0..6 {
            n = c.gate(GateKind::Buf, &[n], Ps(300));
        }
        let mut sim = Simulator::new(&c);
        sim.watch(n);
        let mut t = 0u64;
        for i in 0..200u64 {
            // Irregular pulse train, mostly shorter than the gate delay.
            t += 40 + (i % 7) * 35;
            sim.schedule(a, i % 2 == 0, Ps(t));
        }
        sim.run_until(Ps(1_000_000));
        assert!(sim.stats.events_cancelled > 10, "workload must actually cancel");
        assert_eq!(
            sim.stats.cancelled_reclaimed, sim.stats.events_cancelled,
            "every cancellation reclaimed once the queue drains"
        );
        assert_eq!(sim.outstanding_cancellations(), 0, "lazy-deletion set must drain");
    }

    #[test]
    fn mux_selects_arcs() {
        let mut c = Circuit::new();
        let start = c.net();
        let sel = c.net(); // 0 initially
        let out = c.pdl_element(start, sel, Ps(400), Ps(600), Ps(124));
        let mut sim = Simulator::new(&c);
        sim.watch(out);
        sim.schedule(start, true, Ps(0));
        sim.run_until(Ps(100_000));
        // sel=0 → slow arc: 600 ps stage delay total.
        assert_eq!(sim.first_edge(out, true), Some(Ps(600)));
    }

    #[test]
    fn mux_fast_arc_with_sel_high() {
        let mut c = Circuit::new();
        let start = c.net();
        let sel = c.net_init(true);
        let out = c.pdl_element(start, sel, Ps(400), Ps(600), Ps(124));
        let mut sim = Simulator::new(&c);
        sim.watch(out);
        sim.schedule(start, true, Ps(0));
        sim.run_until(Ps(100_000));
        assert_eq!(sim.first_edge(out, true), Some(Ps(400)));
    }

    #[test]
    fn transparent_latch_holds_when_opaque() {
        let mut c = Circuit::new();
        let en = c.net_init(true);
        let d = c.net();
        let q = c.gate(GateKind::LatchT, &[en, d], Ps(50));
        let mut sim = Simulator::new(&c);
        sim.watch(q);
        sim.schedule(d, true, Ps(100)); // transparent: passes
        sim.schedule(en, false, Ps(300)); // close latch
        sim.schedule(d, false, Ps(400)); // must NOT pass
        sim.run_until(Ps(10_000));
        assert_eq!(sim.trace(q), &[(Ps(150), true)]);
        assert!(sim.level(q));
    }

    #[test]
    fn xnor_ring_reaches_fixpoint() {
        // MOUSETRAP enable logic shape: en = XNOR(req, ack).
        let mut c = Circuit::new();
        let req = c.net();
        let ack = c.net();
        let en = c.gate(GateKind::Xnor2, &[req, ack], Ps(80));
        let mut sim = Simulator::new(&c);
        sim.watch(en);
        sim.schedule(req, true, Ps(0)); // en: 1→0 (after init eval)
        sim.schedule(ack, true, Ps(500)); // en: 0→1
        sim.run_until(Ps(10_000));
        // Initial levels are (0,0) → XNOR=1 but initial net level is 0: the
        // first evaluation happens on the req edge.
        let tr = sim.trace(en);
        assert!(tr.contains(&(Ps(580), true)), "trace {tr:?}");
    }

    #[test]
    fn deterministic_event_order() {
        let build = || {
            let mut c = Circuit::new();
            let a = c.net();
            let b = c.delay_net(a, Ps(10));
            let d = c.delay_net(a, Ps(10));
            let o = c.gate(GateKind::Xor2, &[b, d], Ps(10));
            (c, a, o)
        };
        let run = || {
            let (c, a, o) = build();
            let mut sim = Simulator::new(&c);
            sim.watch(o);
            sim.schedule(a, true, Ps(0));
            sim.run_until(Ps(1000));
            (sim.trace(o).to_vec(), sim.stats)
        };
        assert_eq!(run(), run());
    }
}
