//! Minimal blocking client for the wire protocol.
//!
//! One [`Client`] owns one TCP connection and issues one request at a
//! time (send, then wait for the reply). Server-side typed failures —
//! unknown model, width mismatch, a shed request, an accept-time
//! `OVERLOADED` refusal — surface as [`ClientError::Server`] carrying
//! the protocol error code, so callers can distinguish "retry later"
//! (`QUEUE_FULL`, `OVERLOADED`) from "fix the request" without string
//! matching.

use std::io::{self, BufReader};
use std::net::{TcpStream, ToSocketAddrs};

use crate::tm::BitVec64;

use super::codec::{read_frame, write_frame, WireError};
use super::protocol::{
    code_name, ErrorMsg, InferRequestMsg, InferResponseMsg, Kind, ModelInfoMsg, ModelQueryMsg,
};

/// Everything a client call can fail with.
#[derive(Debug)]
pub enum ClientError {
    /// Transport failure.
    Io(io::Error),
    /// The server's bytes broke the framing contract.
    Wire(WireError),
    /// A structurally valid exchange that made no protocol sense (e.g.
    /// an unexpected frame kind, a correlation-id mismatch).
    Protocol(String),
    /// The server answered with a typed error frame.
    Server { code: u16, message: String },
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "i/o error: {e}"),
            ClientError::Wire(e) => write!(f, "wire error: {e}"),
            ClientError::Protocol(msg) => write!(f, "protocol error: {msg}"),
            ClientError::Server { code, message } => {
                write!(f, "server error {} ({code}): {message}", code_name(*code))
            }
        }
    }
}

impl std::error::Error for ClientError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ClientError::Io(e) => Some(e),
            ClientError::Wire(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for ClientError {
    fn from(e: io::Error) -> ClientError {
        ClientError::Io(e)
    }
}

impl From<WireError> for ClientError {
    fn from(e: WireError) -> ClientError {
        ClientError::Wire(e)
    }
}

/// One blocking connection to a serving front end.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_corr: u64,
}

impl Client {
    /// Connect to a running `serve --listen` front end.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Client, ClientError> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer, next_corr: 1 })
    }

    fn bump(&mut self) -> u64 {
        let corr = self.next_corr;
        self.next_corr += 1;
        corr
    }

    /// Read one frame, surfacing error frames as [`ClientError::Server`]
    /// whatever their correlation id (connection-scoped refusals arrive
    /// with `corr = 0`).
    fn read_reply(&mut self) -> Result<(Kind, Vec<u8>), ClientError> {
        let (kind, payload) = read_frame(&mut self.reader)?
            .ok_or_else(|| ClientError::Protocol("server closed the connection".into()))?;
        let kind = Kind::from_u8(kind)
            .ok_or_else(|| ClientError::Protocol(format!("unknown frame kind {kind}")))?;
        if kind == Kind::Error {
            let err = ErrorMsg::decode(&payload).map_err(ClientError::Protocol)?;
            return Err(ClientError::Server { code: err.code, message: err.message });
        }
        Ok((kind, payload))
    }

    /// Query one served model's shape (feature width, class count,
    /// hot-swap generation).
    pub fn model_info(&mut self, model: &str) -> Result<ModelInfoMsg, ClientError> {
        let corr = self.bump();
        let q = ModelQueryMsg { corr, model: model.to_string() };
        write_frame(&mut self.writer, Kind::ModelQuery.as_u8(), &q.encode())?;
        let (kind, payload) = self.read_reply()?;
        if kind != Kind::ModelInfo {
            return Err(ClientError::Protocol(format!(
                "expected ModelInfo, got frame kind {}",
                kind.as_u8()
            )));
        }
        let info = ModelInfoMsg::decode(&payload).map_err(ClientError::Protocol)?;
        if info.corr != corr {
            return Err(ClientError::Protocol(format!(
                "correlation mismatch: sent {corr}, got {}",
                info.corr
            )));
        }
        Ok(info)
    }

    /// Run one inference on a row already in packed form (`u64` words,
    /// LSB-first, zero tail bits).
    pub fn infer_packed(
        &mut self,
        model: &str,
        n_features: usize,
        words: Vec<u64>,
    ) -> Result<InferResponseMsg, ClientError> {
        let corr = self.bump();
        let req = InferRequestMsg {
            corr,
            model: model.to_string(),
            n_features: n_features as u32,
            words,
        };
        write_frame(&mut self.writer, Kind::InferRequest.as_u8(), &req.encode())?;
        let (kind, payload) = self.read_reply()?;
        if kind != Kind::InferResponse {
            return Err(ClientError::Protocol(format!(
                "expected InferResponse, got frame kind {}",
                kind.as_u8()
            )));
        }
        let resp = InferResponseMsg::decode(&payload).map_err(ClientError::Protocol)?;
        if resp.corr != corr {
            return Err(ClientError::Protocol(format!(
                "correlation mismatch: sent {corr}, got {}",
                resp.corr
            )));
        }
        Ok(resp)
    }

    /// Run one inference on a Boolean feature row (packed here, once).
    pub fn infer(
        &mut self,
        model: &str,
        features: &[bool],
    ) -> Result<InferResponseMsg, ClientError> {
        let packed = BitVec64::from_bools(features);
        let n = packed.len();
        self.infer_packed(model, n, packed.into_words())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_error_display_names_the_code() {
        let e = ClientError::Server {
            code: super::super::protocol::code::QUEUE_FULL,
            message: "shed".into(),
        };
        let s = e.to_string();
        assert!(s.contains("queue-full") && s.contains('3') && s.contains("shed"), "{s}");
        assert!(ClientError::Protocol("odd".into()).to_string().contains("odd"));
    }
}
