//! Frame codec: length-prefixed framing over any `Read`/`Write` pair.
//!
//! A frame is a fixed 12-byte header followed by `payload_len` payload
//! bytes:
//!
//! ```text
//! offset  size  field
//!      0     4  magic        = b"TDPC"
//!      4     1  version      = 1
//!      5     1  kind         (see [`super::protocol::Kind`])
//!      6     2  reserved     = 0
//!      8     4  payload_len  (u32 LE, ≤ MAX_PAYLOAD)
//! ```
//!
//! The declared payload length is validated against
//! [`super::protocol::MAX_PAYLOAD`] **before** the payload buffer is
//! allocated, so a hostile header can never drive an allocation. A clean
//! EOF at a frame boundary reads as `Ok(None)`; an EOF mid-header or
//! mid-payload is an [`std::io::ErrorKind::UnexpectedEof`] I/O error.

use std::io::{self, Read, Write};

use super::protocol::{HEADER_LEN, MAGIC, MAX_PAYLOAD, VERSION};

/// Everything that can go wrong reading a frame off the wire.
#[derive(Debug)]
pub enum WireError {
    /// Transport failure (including mid-frame disconnects, which surface
    /// as [`std::io::ErrorKind::UnexpectedEof`]).
    Io(io::Error),
    /// The first four bytes were not [`MAGIC`] — the peer is not speaking
    /// this protocol at all.
    BadMagic([u8; 4]),
    /// The peer speaks a protocol version this build does not.
    VersionMismatch { got: u8 },
    /// The header declared a payload larger than [`MAX_PAYLOAD`]; the
    /// payload was neither allocated nor read.
    TooLarge { declared: u32, limit: u32 },
    /// A structurally valid frame carried a payload that failed to decode.
    Protocol(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "i/o error: {e}"),
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:?}"),
            WireError::VersionMismatch { got } => write!(
                f,
                "protocol version mismatch: peer speaks v{got}, this build speaks v{VERSION}"
            ),
            WireError::TooLarge { declared, limit } => {
                write!(f, "declared payload length {declared} exceeds the limit {limit}")
            }
            WireError::Protocol(msg) => write!(f, "protocol error: {msg}"),
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> WireError {
        WireError::Io(e)
    }
}

/// Write one frame (header + payload) into `w` *without* flushing — the
/// building block for coalesced writes: a writer that knows more frames
/// are ready queues them all into its `BufWriter` and flushes once (see
/// `server::conn::writer_loop`).
pub fn write_frame_buffered<W: Write>(w: &mut W, kind: u8, payload: &[u8]) -> io::Result<()> {
    debug_assert!(payload.len() <= MAX_PAYLOAD as usize);
    let mut header = [0u8; HEADER_LEN];
    header[..4].copy_from_slice(&MAGIC);
    header[4] = VERSION;
    header[5] = kind;
    // bytes 6..8 reserved, zero
    header[8..12].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(payload)
}

/// Write one frame (header + payload). The caller is responsible for any
/// buffering; this flushes so a lone frame is never stuck in a
/// `BufWriter`.
pub fn write_frame<W: Write>(w: &mut W, kind: u8, payload: &[u8]) -> io::Result<()> {
    write_frame_buffered(w, kind, payload)?;
    w.flush()
}

/// Fill `buf` from `r`. Returns `Ok(false)` on a clean EOF before the
/// first byte, `Err(UnexpectedEof)` on an EOF after a partial read.
fn read_exact_or_eof<R: Read>(r: &mut R, buf: &mut [u8]) -> io::Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(false);
                }
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed mid-frame",
                ));
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Read one frame. `Ok(None)` means the peer closed cleanly at a frame
/// boundary. `Ok(Some((kind, payload)))` is one complete frame; the kind
/// byte is returned raw so callers can answer unknown kinds explicitly.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<(u8, Vec<u8>)>, WireError> {
    let mut header = [0u8; HEADER_LEN];
    if !read_exact_or_eof(r, &mut header)? {
        return Ok(None);
    }
    if header[..4] != MAGIC {
        return Err(WireError::BadMagic(header[..4].try_into().unwrap()));
    }
    if header[4] != VERSION {
        return Err(WireError::VersionMismatch { got: header[4] });
    }
    let kind = header[5];
    let len = u32::from_le_bytes(header[8..12].try_into().unwrap());
    // The cap check precedes the allocation: a hostile length field is
    // refused before it can cost memory.
    if len > MAX_PAYLOAD {
        return Err(WireError::TooLarge { declared: len, limit: MAX_PAYLOAD });
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    Ok(Some((kind, payload)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn frame_bytes(kind: u8, payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        write_frame(&mut out, kind, payload).unwrap();
        out
    }

    #[test]
    fn roundtrip_including_empty_payload() {
        for payload in [&b""[..], b"x", b"hello frame"] {
            let bytes = frame_bytes(3, payload);
            assert_eq!(bytes.len(), HEADER_LEN + payload.len());
            let mut cur = Cursor::new(bytes);
            let (kind, got) = read_frame(&mut cur).unwrap().unwrap();
            assert_eq!(kind, 3);
            assert_eq!(got, payload);
            // The stream is now at a clean frame boundary.
            assert!(read_frame(&mut cur).unwrap().is_none());
        }
    }

    #[test]
    fn back_to_back_frames() {
        let mut bytes = frame_bytes(1, b"first");
        bytes.extend_from_slice(&frame_bytes(2, b"second"));
        let mut cur = Cursor::new(bytes);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), (1, b"first".to_vec()));
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), (2, b"second".to_vec()));
        assert!(read_frame(&mut cur).unwrap().is_none());
    }

    #[test]
    fn buffered_frames_coalesce_behind_one_flush() {
        // The coalesced-writer building block: several frames queue into
        // one BufWriter, nothing reaches the sink until the single
        // flush, and the byte stream is identical to per-frame writes.
        struct CountingSink {
            bytes: Vec<u8>,
            writes: usize,
        }
        impl Write for CountingSink {
            fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
                self.writes += 1;
                self.bytes.extend_from_slice(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let mut w = io::BufWriter::new(CountingSink { bytes: Vec::new(), writes: 0 });
        write_frame_buffered(&mut w, 1, b"first").unwrap();
        write_frame_buffered(&mut w, 2, b"second").unwrap();
        assert_eq!(w.get_ref().writes, 0, "nothing hits the sink before the flush");
        w.flush().unwrap();
        let sink = w.into_inner().unwrap();
        assert_eq!(sink.writes, 1, "both frames left in one write");
        let mut expect = frame_bytes(1, b"first");
        expect.extend_from_slice(&frame_bytes(2, b"second"));
        assert_eq!(sink.bytes, expect);
    }

    #[test]
    fn clean_eof_is_none_partial_header_is_unexpected_eof() {
        let mut empty = Cursor::new(Vec::<u8>::new());
        assert!(read_frame(&mut empty).unwrap().is_none());

        let bytes = frame_bytes(1, b"payload");
        for cut in 1..HEADER_LEN {
            let mut cur = Cursor::new(bytes[..cut].to_vec());
            match read_frame(&mut cur) {
                Err(WireError::Io(e)) => {
                    assert_eq!(e.kind(), io::ErrorKind::UnexpectedEof, "cut={cut}")
                }
                other => panic!("cut={cut}: expected UnexpectedEof, got {other:?}"),
            }
        }
    }

    #[test]
    fn truncated_payload_is_unexpected_eof() {
        let bytes = frame_bytes(1, b"full payload body");
        for cut in HEADER_LEN..bytes.len() {
            let mut cur = Cursor::new(bytes[..cut].to_vec());
            match read_frame(&mut cur) {
                Err(WireError::Io(e)) => {
                    assert_eq!(e.kind(), io::ErrorKind::UnexpectedEof, "cut={cut}")
                }
                other => panic!("cut={cut}: expected UnexpectedEof, got {other:?}"),
            }
        }
    }

    #[test]
    fn garbage_magic_is_rejected() {
        let mut bytes = frame_bytes(1, b"p");
        bytes[0] = b'X';
        match read_frame(&mut Cursor::new(bytes)) {
            Err(WireError::BadMagic(m)) => assert_eq!(&m, b"XDPC"),
            other => panic!("expected BadMagic, got {other:?}"),
        }
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut bytes = frame_bytes(1, b"p");
        bytes[4] = VERSION + 1;
        match read_frame(&mut Cursor::new(bytes)) {
            Err(WireError::VersionMismatch { got }) => assert_eq!(got, VERSION + 1),
            other => panic!("expected VersionMismatch, got {other:?}"),
        }
    }

    #[test]
    fn oversized_declared_length_rejected_before_reading_payload() {
        // A header declaring u32::MAX with *no* payload bytes behind it:
        // if the length check ran after allocation/read we would see an
        // UnexpectedEof (or worse, a 4 GiB allocation). TooLarge proves
        // the check fires first.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.push(VERSION);
        bytes.push(1);
        bytes.extend_from_slice(&[0, 0]);
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        match read_frame(&mut Cursor::new(bytes)) {
            Err(WireError::TooLarge { declared, limit }) => {
                assert_eq!(declared, u32::MAX);
                assert_eq!(limit, MAX_PAYLOAD);
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
        // One past the cap is refused; exactly at the cap passes the
        // length check (and then hits EOF reading the absent payload).
        let mut at_cap = Vec::new();
        at_cap.extend_from_slice(&MAGIC);
        at_cap.push(VERSION);
        at_cap.push(1);
        at_cap.extend_from_slice(&[0, 0]);
        at_cap.extend_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(matches!(
            read_frame(&mut Cursor::new(at_cap)),
            Err(WireError::TooLarge { .. })
        ));
    }

    #[test]
    fn wire_error_display_names_the_failure() {
        let cases: Vec<(WireError, &str)> = vec![
            (WireError::BadMagic(*b"ABCD"), "magic"),
            (WireError::VersionMismatch { got: 9 }, "version"),
            (WireError::TooLarge { declared: 10, limit: 5 }, "exceeds"),
            (WireError::Protocol("x".into()), "protocol"),
            (io::Error::new(io::ErrorKind::UnexpectedEof, "gone").into(), "i/o"),
        ];
        for (e, needle) in cases {
            assert!(e.to_string().contains(needle), "{e}");
        }
    }
}
