//! Per-connection protocol handling.
//!
//! Each accepted connection gets two threads: the *reader* decodes
//! frames and submits them into the coordinator (so a client may
//! pipeline many requests without waiting), and the *writer* answers
//! them **in submission order** — it consumes a queue of pending reply
//! receivers and encodes each reply as it resolves. Per-request reply
//! channels give exact error attribution (a shed row answers only its
//! own frame) without a thread per request; the coordinator's
//! exactly-one-reply contract guarantees the writer never waits on a
//! request forever, so the drain on disconnect terminates.
//!
//! Framing violations — garbage magic, a version this build does not
//! speak, an over-cap declared length, an undecodable payload — are
//! answered with a best-effort `BAD_FRAME` error frame (`corr = 0`) and
//! then the connection is closed: after a framing error the byte stream
//! has no trustworthy frame boundary left to resynchronize on.

use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::{mpsc, Arc};

use crate::coordinator::{await_reply, Coordinator, Reply};
use crate::tm::BitVec64;

use super::codec::{read_frame, write_frame, write_frame_buffered, WireError};
use super::protocol::{
    code, error_code, ErrorMsg, InferRequestMsg, InferResponseMsg, Kind, ModelInfoMsg,
    ModelQueryMsg,
};

/// One unit of writer-queue work, enqueued in submission order.
enum Out {
    /// A submitted inference whose reply is still in flight.
    Pending { corr: u64, rx: mpsc::Receiver<Reply> },
    /// An already-encoded frame (model info, protocol errors).
    Frame { kind: Kind, payload: Vec<u8> },
}

/// Serve one accepted connection to completion. Runs on its own thread
/// (spawned by the listener); returns when the peer disconnects or
/// breaks the protocol.
pub(super) fn handle(stream: TcpStream, coord: Arc<Coordinator>) {
    let _ = stream.set_nodelay(true);
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(e) => {
            log::warn!("server: could not clone a connection stream: {e}");
            return;
        }
    };
    let (out_tx, out_rx) = mpsc::channel::<Out>();
    let writer = std::thread::Builder::new()
        .name("tdpc-conn-writer".to_string())
        .spawn(move || writer_loop(write_half, out_rx));
    let writer = match writer {
        Ok(j) => j,
        Err(e) => {
            log::warn!("server: could not spawn a connection writer: {e}");
            return;
        }
    };

    let mut reader = BufReader::new(&stream);
    loop {
        match read_frame(&mut reader) {
            Ok(Some((kind, payload))) => {
                if !dispatch_frame(kind, &payload, &coord, &out_tx) {
                    break;
                }
            }
            Ok(None) => break, // clean close at a frame boundary
            Err(WireError::Io(_)) => break, // peer gone mid-frame; nobody to answer
            Err(e) => {
                // Framing violation with a live peer: name the offense,
                // then hang up — the stream has no trustworthy frame
                // boundary left.
                send_error(&out_tx, 0, code::BAD_FRAME, &e.to_string());
                break;
            }
        }
    }

    // Let the writer drain every queued reply (the coordinator answers
    // each submitted request exactly once, so this terminates), then
    // drop the socket.
    drop(out_tx);
    let _ = writer.join();
    let _ = stream.shutdown(Shutdown::Both);
}

/// Decode and act on one frame. Returns `false` when the connection must
/// close (protocol violation).
fn dispatch_frame(
    kind: u8,
    payload: &[u8],
    coord: &Coordinator,
    out: &mpsc::Sender<Out>,
) -> bool {
    match Kind::from_u8(kind) {
        Some(Kind::InferRequest) => match InferRequestMsg::decode(payload) {
            Ok(req) => {
                // Decode validated the word count and zero tail bits, so
                // the packed row is constructible as-is — no unpack,
                // no repack, no bool slice.
                let features = BitVec64::from_words(req.n_features as usize, req.words);
                let (tx, rx) = mpsc::channel::<Reply>();
                coord.submit_packed_named(&req.model, features, tx);
                let _ = out.send(Out::Pending { corr: req.corr, rx });
                true
            }
            Err(msg) => {
                send_error(out, 0, code::BAD_FRAME, &format!("bad InferRequest: {msg}"));
                false
            }
        },
        Some(Kind::ModelQuery) => match ModelQueryMsg::decode(payload) {
            Ok(q) => {
                let info = coord.model_id(&q.model).map(|mid| ModelInfoMsg {
                    corr: q.corr,
                    model: q.model.clone(),
                    n_features: coord.n_features_for(mid).unwrap_or(0) as u32,
                    n_classes: coord.n_classes_for(mid).unwrap_or(0) as u32,
                    generation: coord.generation_for(mid).unwrap_or(0),
                });
                match info {
                    Some(info) => {
                        let _ = out.send(Out::Frame {
                            kind: Kind::ModelInfo,
                            payload: info.encode(),
                        });
                    }
                    None => send_error(
                        out,
                        q.corr,
                        code::UNKNOWN_MODEL,
                        &format!("model {:?} is not served by this pool", q.model),
                    ),
                }
                true
            }
            Err(msg) => {
                send_error(out, 0, code::BAD_FRAME, &format!("bad ModelQuery: {msg}"));
                false
            }
        },
        // Server→client kinds arriving at the server, or unknown bytes:
        // the peer is confused; close after naming the offense.
        Some(other) => {
            send_error(
                out,
                0,
                code::BAD_FRAME,
                &format!("unexpected client frame kind {}", other.as_u8()),
            );
            false
        }
        None => {
            send_error(out, 0, code::BAD_FRAME, &format!("unknown frame kind {kind}"));
            false
        }
    }
}

fn send_error(out: &mpsc::Sender<Out>, corr: u64, code: u16, message: &str) {
    let msg = ErrorMsg { corr, code, message: message.to_string() };
    let _ = out.send(Out::Frame { kind: Kind::Error, payload: msg.encode() });
}

/// The writer thread: answer queued work in submission order, coalescing
/// ready replies. Each wakeup drains the queue as far as it can without
/// blocking — every item whose reply has already resolved is encoded
/// into the `BufWriter` — and flushes **once**, so a pipelining client
/// whose batch resolved together costs one syscall, not one per
/// response. The in-order contract is preserved by how the drain stalls:
/// when the *head* reply is still in flight, the frames written so far
/// are flushed first (nothing ready is ever held back behind a wait),
/// then the loop blocks on that head reply alone. A write failure (peer
/// gone) stops the loop; remaining `Pending` receivers are dropped,
/// which is safe — the coordinator's reply sends are best-effort by
/// contract.
fn writer_loop(stream: TcpStream, rx: mpsc::Receiver<Out>) {
    let mut w = BufWriter::new(stream);
    while let Ok(first) = rx.recv() {
        let mut item = first;
        loop {
            let (kind, payload) = match item {
                Out::Pending { corr, rx: reply_rx } => match reply_rx.try_recv() {
                    Ok(reply) => (Kind::from_reply(&reply), encode_reply(corr, reply)),
                    Err(_) => {
                        // Head-of-line reply still in flight (or its pool
                        // is gone): ship what is buffered, then fall back
                        // to the one shared blocking wait (also behind
                        // `infer_blocking`) — a torn-down pool reads as a
                        // typed ShuttingDown, never a hang or panic.
                        if w.flush().is_err() {
                            return;
                        }
                        let reply = await_reply(&reply_rx);
                        (Kind::from_reply(&reply), encode_reply(corr, reply))
                    }
                },
                Out::Frame { kind, payload } => (kind, payload),
            };
            if write_frame_buffered(&mut w, kind.as_u8(), &payload).is_err() {
                return;
            }
            // Keep draining while more work is already queued; an empty
            // (or closed) queue ends the wakeup, and the flush below
            // publishes everything this drain coalesced.
            match rx.try_recv() {
                Ok(next) => item = next,
                Err(_) => break,
            }
        }
        if w.flush().is_err() {
            return;
        }
    }
    let _ = w.flush();
}

impl Kind {
    fn from_reply(reply: &Reply) -> Kind {
        match reply {
            Ok(_) => Kind::InferResponse,
            Err(_) => Kind::Error,
        }
    }
}

/// Encode one coordinator reply as a wire payload: a success carries the
/// generation, argmax, and class sums; a typed [`crate::coordinator::InferError`]
/// maps to its protocol error code with the human-readable message.
fn encode_reply(corr: u64, reply: Reply) -> Vec<u8> {
    match reply {
        Ok(resp) => InferResponseMsg {
            corr,
            generation: resp.generation,
            pred: resp.pred as u32,
            sums: resp.sums,
        }
        .encode(),
        Err(e) => ErrorMsg { corr, code: error_code(&e), message: e.to_string() }.encode(),
    }
}

/// Refuse a connection at accept time with a single `OVERLOADED` error
/// frame (`corr = 0`), then close. Best-effort: the refused peer may
/// already be gone.
pub(super) fn refuse(stream: TcpStream, message: &str) {
    let msg = ErrorMsg { corr: 0, code: code::OVERLOADED, message: message.to_string() };
    let mut w = BufWriter::new(&stream);
    let _ = write_frame(&mut w, Kind::Error.as_u8(), &msg.encode());
    let _ = w.flush();
    drop(w);
    let _ = stream.shutdown(Shutdown::Both);
}
