//! Closed- and open-loop load generation against a serving front end.
//!
//! The harness measures the *end-to-end* serving path — TCP, framing,
//! admission, batching, the backend forward pass, and the reply wire —
//! under a configurable tenant mix and burst shape, and reports goodput,
//! shed rate, and latency percentiles in a stable JSON schema
//! (`BENCH_serving.json`, schema `tdpc-bench-serving/v1`) so CI can keep
//! a perf datapoint per run.
//!
//! Two arrival disciplines:
//!
//! * **closed-loop** ([`Mode::Closed`]): `conns` connections, each with
//!   exactly one request outstanding — measures the pipeline's capacity
//!   at a fixed concurrency;
//! * **open-loop** ([`Mode::Open`]): arrivals are *scheduled* at a fixed
//!   rate on a shared clock and claimed by `conns` sender threads.
//!   Latency is measured from each request's **scheduled** arrival time,
//!   not from when a sender got around to it, so a slow server inflates
//!   the tail instead of silently slowing the load (the classic
//!   coordinated-omission trap).
//!
//! Burst shapes gate the schedule: [`BurstShape::Square`] concentrates
//! the same arrival process into a duty window of each period (e.g.
//! `square:100:20` → all load lands in the first 20 ms of every 100 ms),
//! which is what drives admission control into visible shedding.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::tm::bits::{tail_mask, words_for};
use crate::util::json::{self, num, obj, s, Value};
use crate::util::stats::{mean, percentile};
use crate::util::SplitMix64;

use super::client::{Client, ClientError};
use super::protocol::code;

/// Arrival discipline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mode {
    /// `conns` connections, one outstanding request each.
    Closed { conns: usize },
    /// Arrivals scheduled at `rate_rps` on a shared clock, sent by
    /// `conns` sender threads.
    Open { rate_rps: f64, conns: usize },
}

impl Mode {
    pub fn name(&self) -> &'static str {
        match self {
            Mode::Closed { .. } => "closed",
            Mode::Open { .. } => "open",
        }
    }
}

/// When, within each period, arrivals are admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BurstShape {
    /// Arrivals flow whenever the discipline produces them.
    Steady,
    /// Arrivals only land inside the first `duty_pct`% of each `period`;
    /// an arrival scheduled in the off-window is deferred to the start
    /// of the next period.
    Square { period: Duration, duty_pct: u8 },
}

impl BurstShape {
    /// Parse `steady` or `square:<period_ms>:<duty_pct>`.
    pub fn from_name(name: &str) -> Result<BurstShape> {
        if name == "steady" {
            return Ok(BurstShape::Steady);
        }
        if let Some(rest) = name.strip_prefix("square:") {
            let (period_ms, duty) = rest
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("expected square:<period_ms>:<duty_pct>"))?;
            let period_ms: u64 = period_ms
                .parse()
                .with_context(|| format!("square burst period {period_ms:?} must be integer ms"))?;
            let duty_pct: u8 = duty
                .parse()
                .with_context(|| format!("square burst duty {duty:?} must be an integer percent"))?;
            ensure!(period_ms >= 1, "square burst period must be ≥ 1 ms");
            ensure!(
                (1..=100).contains(&duty_pct),
                "square burst duty must be in 1..=100 percent"
            );
            return Ok(BurstShape::Square {
                period: Duration::from_millis(period_ms),
                duty_pct,
            });
        }
        bail!("unknown burst shape {name:?} (expected: steady, square:<period_ms>:<duty_pct>)")
    }

    pub fn name(&self) -> String {
        match self {
            BurstShape::Steady => "steady".to_string(),
            BurstShape::Square { period, duty_pct } => {
                format!("square:{}:{duty_pct}", period.as_millis())
            }
        }
    }

    /// Earliest admissible time at or after `t`. Pure, so the schedule
    /// is unit-testable without a clock.
    pub fn next_on(&self, t: Duration) -> Duration {
        match *self {
            BurstShape::Steady => t,
            BurstShape::Square { period, duty_pct } => {
                let p = period.as_nanos() as u64;
                let on = p * u64::from(duty_pct) / 100;
                let ts = t.as_nanos() as u64;
                let phase = ts % p;
                if phase < on {
                    t
                } else {
                    Duration::from_nanos(ts - phase + p)
                }
            }
        }
    }
}

/// Parse a tenant mix like `"tenant_a:3,tenant_b:1"` (bare names weigh 1).
pub fn parse_mix(text: &str) -> Result<Vec<(String, u32)>> {
    let mut mix = Vec::new();
    for part in text.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, weight) = match part.rsplit_once(':') {
            Some((name, w)) => {
                let weight: u32 = w
                    .parse()
                    .with_context(|| format!("tenant weight {w:?} must be a positive integer"))?;
                ensure!(weight >= 1, "tenant weight for {name:?} must be ≥ 1");
                (name.to_string(), weight)
            }
            None => (part.to_string(), 1),
        };
        ensure!(!name.is_empty(), "empty tenant name in mix {text:?}");
        mix.push((name, weight));
    }
    ensure!(!mix.is_empty(), "the tenant mix must name at least one model");
    Ok(mix)
}

/// Everything one load run needs.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Server address, e.g. `127.0.0.1:4700`.
    pub addr: String,
    pub mode: Mode,
    /// Wall-clock budget; senders stop scheduling past this.
    pub duration: Duration,
    /// Optional request budget shared across senders (`None` = bounded
    /// by duration only).
    pub max_requests: Option<u64>,
    /// Weighted tenant mix (see [`parse_mix`]).
    pub models: Vec<(String, u32)>,
    pub burst: BurstShape,
    pub seed: u64,
}

/// What one run measured.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub mode: String,
    pub conns: usize,
    /// Target arrival rate (open-loop only; 0 for closed-loop).
    pub rate_rps: f64,
    pub burst: String,
    pub duration_s: f64,
    pub models: Vec<String>,
    /// Requests actually sent (scheduled arrivals that got a connection).
    pub sent: u64,
    /// Requests answered with a prediction.
    pub ok: u64,
    /// Requests shed by admission control (`QUEUE_FULL` frames) or
    /// refused at accept (`OVERLOADED` frames).
    pub shed: u64,
    /// Other typed server errors (unknown model, width, backend).
    pub errors: u64,
    /// Framing/decode violations observed by the client — the CI gate:
    /// any nonzero value here is a protocol bug, not an overload symptom.
    pub protocol_errors: u64,
    /// Reconnections (dropped or refused connections re-established).
    pub reconnects: u64,
    /// `ok / wall` — answered requests per second.
    pub goodput_rps: f64,
    /// `shed / sent`.
    pub shed_rate: f64,
    /// End-to-end latency of answered requests, µs (open-loop: measured
    /// from the *scheduled* arrival, coordinated-omission-free).
    pub lat_mean_us: f64,
    pub lat_p50_us: f64,
    pub lat_p90_us: f64,
    pub lat_p99_us: f64,
    pub lat_p999_us: f64,
    pub lat_max_us: f64,
}

impl LoadReport {
    /// Stable JSON schema `tdpc-bench-serving/v1` — CI uploads this
    /// verbatim as the run's perf datapoint.
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("schema", s("tdpc-bench-serving/v1")),
            ("mode", s(&self.mode)),
            ("conns", num(self.conns as f64)),
            ("rate_rps", num(self.rate_rps)),
            ("burst", s(&self.burst)),
            ("duration_s", num(self.duration_s)),
            (
                "models",
                Value::Arr(self.models.iter().map(|m| s(m)).collect()),
            ),
            ("sent", num(self.sent as f64)),
            ("ok", num(self.ok as f64)),
            ("shed", num(self.shed as f64)),
            ("errors", num(self.errors as f64)),
            ("protocol_errors", num(self.protocol_errors as f64)),
            ("reconnects", num(self.reconnects as f64)),
            ("goodput_rps", num(self.goodput_rps)),
            ("shed_rate", num(self.shed_rate)),
            (
                "latency_us",
                obj(vec![
                    ("mean", num(self.lat_mean_us)),
                    ("p50", num(self.lat_p50_us)),
                    ("p90", num(self.lat_p90_us)),
                    ("p99", num(self.lat_p99_us)),
                    ("p999", num(self.lat_p999_us)),
                    ("max", num(self.lat_max_us)),
                ]),
            ),
        ])
    }

    /// One-paragraph human summary for terminal output.
    pub fn summary(&self) -> String {
        format!(
            "{} mode, {} conns, burst {}: {} sent over {:.2}s → {} ok \
             ({:.0} req/s goodput), {} shed ({:.1}% of sent), {} errors, \
             {} protocol errors, {} reconnects; latency µs \
             p50={:.0} p90={:.0} p99={:.0} p99.9={:.0} max={:.0}",
            self.mode,
            self.conns,
            self.burst,
            self.sent,
            self.duration_s,
            self.ok,
            self.goodput_rps,
            self.shed,
            self.shed_rate * 100.0,
            self.errors,
            self.protocol_errors,
            self.reconnects,
            self.lat_p50_us,
            self.lat_p90_us,
            self.lat_p99_us,
            self.lat_p999_us,
            self.lat_max_us,
        )
    }
}

/// Per-sender tallies, merged after join.
#[derive(Debug, Default)]
struct ThreadStats {
    sent: u64,
    ok: u64,
    shed: u64,
    errors: u64,
    protocol_errors: u64,
    reconnects: u64,
    lat_us: Vec<f64>,
}

impl ThreadStats {
    fn merge(&mut self, other: ThreadStats) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.shed += other.shed;
        self.errors += other.errors;
        self.protocol_errors += other.protocol_errors;
        self.reconnects += other.reconnects;
        self.lat_us.extend(other.lat_us);
    }
}

/// One tenant as a sender thread sees it: name, packed width, and its
/// cumulative weight bound for the weighted pick.
#[derive(Debug, Clone)]
struct Tenant {
    name: String,
    n_features: usize,
    cum_weight: u32,
}

/// Shared sender context (bundled so the worker loop takes one argument).
struct SenderCtx {
    addr: String,
    tenants: Vec<Tenant>,
    total_weight: u32,
    burst: BurstShape,
    deadline: Duration,
    /// Open-loop arrival counter / shared request budget. In closed-loop
    /// runs it only enforces `max_requests`.
    next_arrival: AtomicU64,
    max_requests: u64,
    /// Open-loop inter-arrival gap in nanoseconds (0 ⇔ closed-loop).
    gap_ns: f64,
    start: Instant,
}

impl SenderCtx {
    /// Claim the next arrival index, or `None` when the request budget
    /// is spent.
    fn claim(&self) -> Option<u64> {
        let i = self.next_arrival.fetch_add(1, Ordering::Relaxed);
        if i >= self.max_requests {
            None
        } else {
            Some(i)
        }
    }

    /// The claimed arrival's scheduled send time, after burst gating.
    /// `None` when it falls past the deadline.
    fn schedule(&self, arrival: u64) -> Option<Duration> {
        let base = if self.gap_ns > 0.0 {
            Duration::from_nanos((arrival as f64 * self.gap_ns) as u64)
        } else {
            // Closed-loop: "now" is the schedule; only the burst gate
            // defers it.
            self.start.elapsed()
        };
        let gated = self.burst.next_on(base);
        if gated >= self.deadline {
            None
        } else {
            Some(gated)
        }
    }

    /// Weighted tenant pick.
    fn pick<'a>(&'a self, rng: &mut SplitMix64) -> &'a Tenant {
        let draw = rng.next_below(self.total_weight as usize) as u32;
        self.tenants
            .iter()
            .find(|t| draw < t.cum_weight)
            .expect("cumulative weights cover the draw range")
    }
}

/// Connect with capped exponential backoff; counts each failed attempt.
/// `None` once the deadline passes.
fn connect_with_backoff(ctx: &SenderCtx, stats: &mut ThreadStats) -> Option<Client> {
    let mut wait = Duration::from_millis(1);
    loop {
        if ctx.start.elapsed() >= ctx.deadline {
            return None;
        }
        match Client::connect(&ctx.addr) {
            Ok(c) => return Some(c),
            Err(_) => {
                stats.reconnects += 1;
                std::thread::sleep(wait);
                wait = (wait * 2).min(Duration::from_millis(100));
            }
        }
    }
}

/// One sender thread: claim scheduled arrivals, send them, classify the
/// outcomes.
fn sender_loop(ctx: &SenderCtx, thread_ix: usize, seed: u64) -> ThreadStats {
    let mut rng = SplitMix64::new(seed ^ (0x5EED_0000 + thread_ix as u64));
    let mut stats = ThreadStats::default();
    let mut client: Option<Client> = None;
    while let Some(arrival) = ctx.claim() {
        let Some(sched) = ctx.schedule(arrival) else { break };
        let now = ctx.start.elapsed();
        if sched > now {
            std::thread::sleep(sched - now);
        }
        let c = match client.as_mut() {
            Some(c) => c,
            None => match connect_with_backoff(ctx, &mut stats) {
                Some(c) => client.insert(c),
                None => break,
            },
        };
        let tenant = ctx.pick(&mut rng);
        let words = random_row(&mut rng, tenant.n_features);
        stats.sent += 1;
        match c.infer_packed(&tenant.name, tenant.n_features, words) {
            Ok(_) => {
                stats.ok += 1;
                // Latency from the *scheduled* arrival: backpressure
                // shows up in the tail instead of silently thinning the
                // offered load.
                let e2e = ctx.start.elapsed().saturating_sub(sched);
                stats.lat_us.push(e2e.as_secs_f64() * 1e6);
            }
            Err(ClientError::Server { code: c2, .. }) if c2 == code::QUEUE_FULL => {
                stats.shed += 1;
            }
            Err(ClientError::Server { code: c2, .. }) if c2 == code::OVERLOADED => {
                // Refused at accept: the socket is closing; reconnect.
                stats.shed += 1;
                client = None;
            }
            Err(ClientError::Server { code: c2, .. }) if c2 == code::BAD_FRAME => {
                // The server judged our bytes malformed — a protocol bug
                // by definition, and connection-fatal.
                stats.protocol_errors += 1;
                client = None;
            }
            Err(ClientError::Server { .. }) => {
                stats.errors += 1;
            }
            Err(ClientError::Wire(_)) | Err(ClientError::Protocol(_)) => {
                stats.protocol_errors += 1;
                client = None;
            }
            Err(ClientError::Io(_)) => {
                stats.errors += 1;
                client = None;
            }
        }
    }
    stats
}

/// A random packed feature row of `bits` bits (tail bits zeroed).
fn random_row(rng: &mut SplitMix64, bits: usize) -> Vec<u64> {
    let mut words: Vec<u64> = (0..words_for(bits)).map(|_| rng.next_u64()).collect();
    if let Some(last) = words.last_mut() {
        *last &= tail_mask(bits);
    }
    words
}

/// Run one load measurement. Probes every tenant's shape up front (so an
/// unknown model fails fast, before any load), then drives the arrival
/// schedule through `conns` sender threads and aggregates.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport> {
    ensure!(!cfg.models.is_empty(), "loadgen needs at least one tenant model");
    let (conns, rate_rps) = match cfg.mode {
        Mode::Closed { conns } => (conns, 0.0),
        Mode::Open { rate_rps, conns } => {
            ensure!(rate_rps > 0.0, "open-loop rate must be > 0 req/s");
            (conns, rate_rps)
        }
    };
    ensure!(conns >= 1, "loadgen needs at least one connection");

    // Probe tenant shapes over the wire — validates every model name and
    // learns the width to generate rows at.
    let mut probe = Client::connect(&cfg.addr)
        .map_err(|e| anyhow::anyhow!("connecting to {}: {e}", cfg.addr))?;
    let mut tenants = Vec::with_capacity(cfg.models.len());
    let mut cum = 0u32;
    for (name, weight) in &cfg.models {
        let info = probe
            .model_info(name)
            .map_err(|e| anyhow::anyhow!("probing model {name:?}: {e}"))?;
        cum += weight;
        tenants.push(Tenant {
            name: name.clone(),
            n_features: info.n_features as usize,
            cum_weight: cum,
        });
    }
    drop(probe);

    let ctx = Arc::new(SenderCtx {
        addr: cfg.addr.clone(),
        tenants,
        total_weight: cum,
        burst: cfg.burst,
        deadline: cfg.duration,
        next_arrival: AtomicU64::new(0),
        max_requests: cfg.max_requests.unwrap_or(u64::MAX),
        gap_ns: if rate_rps > 0.0 { 1e9 / rate_rps } else { 0.0 },
        start: Instant::now(),
    });

    let mut handles = Vec::with_capacity(conns);
    for t in 0..conns {
        let ctx = ctx.clone();
        let seed = cfg.seed;
        let h = std::thread::Builder::new()
            .name(format!("tdpc-loadgen-{t}"))
            .spawn(move || sender_loop(&ctx, t, seed))
            .context("spawning a loadgen sender")?;
        handles.push(h);
    }
    let mut total = ThreadStats::default();
    for h in handles {
        match h.join() {
            Ok(stats) => total.merge(stats),
            Err(_) => bail!("a loadgen sender thread panicked"),
        }
    }
    let wall = ctx.start.elapsed().as_secs_f64().max(1e-9);

    Ok(LoadReport {
        mode: cfg.mode.name().to_string(),
        conns,
        rate_rps,
        burst: cfg.burst.name(),
        duration_s: wall,
        models: cfg.models.iter().map(|(n, _)| n.clone()).collect(),
        sent: total.sent,
        ok: total.ok,
        shed: total.shed,
        errors: total.errors,
        protocol_errors: total.protocol_errors,
        reconnects: total.reconnects,
        goodput_rps: total.ok as f64 / wall,
        shed_rate: if total.sent == 0 {
            0.0
        } else {
            total.shed as f64 / total.sent as f64
        },
        lat_mean_us: mean(&total.lat_us),
        lat_p50_us: percentile(&total.lat_us, 50.0),
        lat_p90_us: percentile(&total.lat_us, 90.0),
        lat_p99_us: percentile(&total.lat_us, 99.0),
        lat_p999_us: percentile(&total.lat_us, 99.9),
        lat_max_us: total.lat_us.iter().copied().fold(0.0, f64::max),
    })
}

/// Serialize a report to disk (stable: `util::json` emits object keys
/// in sorted order, so identical reports yield identical bytes).
pub fn write_report(report: &LoadReport, path: &std::path::Path) -> Result<()> {
    std::fs::write(path, json::emit(&report.to_json()) + "\n")
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_shape_parsing() {
        assert_eq!(BurstShape::from_name("steady").unwrap(), BurstShape::Steady);
        assert_eq!(
            BurstShape::from_name("square:100:20").unwrap(),
            BurstShape::Square { period: Duration::from_millis(100), duty_pct: 20 }
        );
        for bad in ["square", "square:0:20", "square:100:0", "square:100:101", "sine"] {
            assert!(BurstShape::from_name(bad).is_err(), "{bad} must be rejected");
        }
        // name() round-trips through from_name().
        for shape in [
            BurstShape::Steady,
            BurstShape::Square { period: Duration::from_millis(50), duty_pct: 7 },
        ] {
            assert_eq!(BurstShape::from_name(&shape.name()).unwrap(), shape);
        }
    }

    #[test]
    fn square_burst_defers_off_window_arrivals() {
        let b = BurstShape::Square { period: Duration::from_millis(100), duty_pct: 20 };
        // In the on-window: pass through unchanged.
        assert_eq!(b.next_on(Duration::from_millis(0)), Duration::from_millis(0));
        assert_eq!(b.next_on(Duration::from_millis(19)), Duration::from_millis(19));
        assert_eq!(b.next_on(Duration::from_millis(119)), Duration::from_millis(119));
        // In the off-window: defer to the next period start.
        assert_eq!(b.next_on(Duration::from_millis(20)), Duration::from_millis(100));
        assert_eq!(b.next_on(Duration::from_millis(99)), Duration::from_millis(100));
        assert_eq!(b.next_on(Duration::from_millis(150)), Duration::from_millis(200));
        // Steady never defers.
        assert_eq!(
            BurstShape::Steady.next_on(Duration::from_millis(37)),
            Duration::from_millis(37)
        );
    }

    #[test]
    fn mix_parsing() {
        assert_eq!(
            parse_mix("a:3,b:1").unwrap(),
            vec![("a".to_string(), 3), ("b".to_string(), 1)]
        );
        assert_eq!(parse_mix("solo").unwrap(), vec![("solo".to_string(), 1)]);
        assert_eq!(
            parse_mix(" a , b:2 ").unwrap(),
            vec![("a".to_string(), 1), ("b".to_string(), 2)]
        );
        for bad in ["", "a:0", "a:x", ":3"] {
            assert!(parse_mix(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn weighted_pick_respects_cumulative_bounds() {
        let ctx = SenderCtx {
            addr: String::new(),
            tenants: vec![
                Tenant { name: "a".into(), n_features: 8, cum_weight: 3 },
                Tenant { name: "b".into(), n_features: 8, cum_weight: 4 },
            ],
            total_weight: 4,
            burst: BurstShape::Steady,
            deadline: Duration::from_secs(1),
            next_arrival: AtomicU64::new(0),
            max_requests: u64::MAX,
            gap_ns: 0.0,
            start: Instant::now(),
        };
        let mut rng = SplitMix64::new(9);
        let mut counts = [0u32; 2];
        for _ in 0..4000 {
            match ctx.pick(&mut rng).name.as_str() {
                "a" => counts[0] += 1,
                _ => counts[1] += 1,
            }
        }
        // 3:1 mix → a ≈ 75% of picks.
        let frac_a = counts[0] as f64 / 4000.0;
        assert!((0.70..0.80).contains(&frac_a), "frac_a = {frac_a}");
    }

    #[test]
    fn open_loop_schedule_is_rate_driven() {
        let ctx = SenderCtx {
            addr: String::new(),
            tenants: Vec::new(),
            total_weight: 1,
            burst: BurstShape::Steady,
            deadline: Duration::from_secs(10),
            next_arrival: AtomicU64::new(0),
            max_requests: u64::MAX,
            gap_ns: 1e6, // 1000 req/s
            start: Instant::now(),
        };
        assert_eq!(ctx.schedule(0).unwrap(), Duration::ZERO);
        assert_eq!(ctx.schedule(1000).unwrap(), Duration::from_secs(1));
        // Past the deadline: no schedule.
        assert!(ctx.schedule(20_000_000).is_none());
    }

    #[test]
    fn request_budget_is_shared() {
        let ctx = SenderCtx {
            addr: String::new(),
            tenants: Vec::new(),
            total_weight: 1,
            burst: BurstShape::Steady,
            deadline: Duration::from_secs(1),
            next_arrival: AtomicU64::new(0),
            max_requests: 3,
            gap_ns: 0.0,
            start: Instant::now(),
        };
        assert_eq!(ctx.claim(), Some(0));
        assert_eq!(ctx.claim(), Some(1));
        assert_eq!(ctx.claim(), Some(2));
        assert_eq!(ctx.claim(), None);
        assert_eq!(ctx.claim(), None);
    }

    #[test]
    fn report_json_schema_is_stable_and_parses() {
        let report = LoadReport {
            mode: "closed".into(),
            conns: 4,
            rate_rps: 0.0,
            burst: "steady".into(),
            duration_s: 1.5,
            models: vec!["a".into(), "b".into()],
            sent: 100,
            ok: 90,
            shed: 10,
            errors: 0,
            protocol_errors: 0,
            reconnects: 2,
            goodput_rps: 60.0,
            shed_rate: 0.1,
            lat_mean_us: 120.0,
            lat_p50_us: 100.0,
            lat_p90_us: 180.0,
            lat_p99_us: 250.0,
            lat_p999_us: 400.0,
            lat_max_us: 512.0,
        };
        let text = json::emit(&report.to_json());
        let back = json::parse(&text).unwrap();
        assert_eq!(back.get("schema").unwrap().as_str().unwrap(), "tdpc-bench-serving/v1");
        assert_eq!(back.get("ok").unwrap().as_usize().unwrap(), 90);
        assert_eq!(back.get("shed").unwrap().as_usize().unwrap(), 10);
        assert!((back.get("shed_rate").unwrap().as_f64().unwrap() - 0.1).abs() < 1e-12);
        let lat = back.get("latency_us").unwrap();
        for key in ["mean", "p50", "p90", "p99", "p999", "max"] {
            assert!(lat.get(key).unwrap().as_f64().unwrap() > 0.0, "{key}");
        }
        assert_eq!(back.get("models").unwrap().as_arr().unwrap().len(), 2);
    }
}
