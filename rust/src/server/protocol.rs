//! Wire protocol v1: message payloads and error codes.
//!
//! Every frame on the wire is a 12-byte header (see [`super::codec`])
//! followed by one of the payloads defined here. All integers are
//! little-endian; feature rows travel *packed* — the same `u64` LSB-first
//! words with zero tail bits that are the request path's native currency
//! (`crate::tm::bits`) — so a request decodes straight into a
//! [`crate::tm::BitVec64`] with no bool materialization on either side.
//!
//! Payload layouts (after the frame header):
//!
//! | kind | payload |
//! |---|---|
//! | `InferRequest` (1) | `corr u64 · name_len u16 · name bytes · n_features u32 · ceil(n/64) × word u64` |
//! | `InferResponse` (2) | `corr u64 · generation u64 · pred u32 · n_classes u32 · n_classes × sum i32` |
//! | `Error` (3) | `corr u64 · code u16 · msg_len u16 · msg bytes` |
//! | `ModelQuery` (4) | `corr u64 · name_len u16 · name bytes` |
//! | `ModelInfo` (5) | `corr u64 · name_len u16 · name bytes · n_features u32 · n_classes u32 · generation u64` |
//!
//! `corr` is an opaque client-chosen correlation id echoed verbatim in the
//! reply, so pipelined clients can match responses to requests (the server
//! answers each connection's requests in submission order). Error frames
//! raised by the server outside any one request (a malformed frame, an
//! accept-time overload refusal) carry `corr = 0`.
//!
//! Decoding is defensive: name and feature-width caps are enforced before
//! any length-driven allocation, trailing payload bytes are rejected, and
//! nonzero tail bits in the last feature word are refused (the packed
//! invariant every downstream popcount relies on).

use crate::coordinator::InferError;
use crate::tm::bits::{tail_mask, words_for};

/// Frame magic: the first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"TDPC";

/// Protocol version this build speaks. A frame with any other version is
/// refused with [`super::codec::WireError::VersionMismatch`].
pub const VERSION: u8 = 1;

/// Frame header length in bytes (magic 4 + version 1 + kind 1 +
/// reserved 2 + payload_len 4).
pub const HEADER_LEN: usize = 12;

/// Hard cap on a frame's declared payload length. [`super::codec::read_frame`]
/// checks the declared length against this *before* allocating the payload
/// buffer, so a hostile 4 GiB length field costs nothing.
pub const MAX_PAYLOAD: u32 = 2 * 1024 * 1024;

/// Cap on a request's declared feature width (1 Mi bits = 16 Ki words).
pub const MAX_FEATURE_BITS: u32 = 1 << 20;

/// Cap on a model name's byte length.
pub const MAX_NAME_LEN: usize = 256;

/// Cap on a response's declared class count.
pub const MAX_CLASSES: u32 = 4096;

/// Frame kinds (header byte 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Kind {
    /// Client → server: one inference request.
    InferRequest = 1,
    /// Server → client: the successful answer to an `InferRequest`.
    InferResponse = 2,
    /// Server → client: a typed failure (see [`code`]).
    Error = 3,
    /// Client → server: look up one served model's shape.
    ModelQuery = 4,
    /// Server → client: the answer to a `ModelQuery`.
    ModelInfo = 5,
}

impl Kind {
    pub fn from_u8(b: u8) -> Option<Kind> {
        match b {
            1 => Some(Kind::InferRequest),
            2 => Some(Kind::InferResponse),
            3 => Some(Kind::Error),
            4 => Some(Kind::ModelQuery),
            5 => Some(Kind::ModelInfo),
            _ => None,
        }
    }

    pub fn as_u8(self) -> u8 {
        self as u8
    }
}

/// Protocol error codes carried by `Error` frames. Codes 1–5 map the
/// coordinator's typed [`InferError`] variants one-to-one (see
/// [`error_code`]); codes ≥ 16 are raised by the serving layer itself.
pub mod code {
    /// [`super::InferError::UnknownModel`].
    pub const UNKNOWN_MODEL: u16 = 1;
    /// [`super::InferError::WidthMismatch`].
    pub const WIDTH_MISMATCH: u16 = 2;
    /// [`super::InferError::QueueFull`] — the request was shed by
    /// admission control; retry later.
    pub const QUEUE_FULL: u16 = 3;
    /// [`super::InferError::BackendFailed`].
    pub const BACKEND_FAILED: u16 = 4;
    /// [`super::InferError::ShuttingDown`].
    pub const SHUTTING_DOWN: u16 = 5;
    /// The client broke the framing or payload contract; the server
    /// closes the connection after sending this (connection-fatal).
    pub const BAD_FRAME: u16 = 16;
    /// The server refused the *connection* at accept time (connection
    /// limit reached, or every worker queue at its bound) — overload is
    /// shed at the socket instead of accumulating in RAM.
    pub const OVERLOADED: u16 = 17;
}

/// The wire error code for a typed coordinator error.
pub fn error_code(e: &InferError) -> u16 {
    match e {
        InferError::UnknownModel { .. } => code::UNKNOWN_MODEL,
        InferError::WidthMismatch { .. } => code::WIDTH_MISMATCH,
        InferError::QueueFull { .. } => code::QUEUE_FULL,
        InferError::BackendFailed(_) => code::BACKEND_FAILED,
        InferError::ShuttingDown => code::SHUTTING_DOWN,
    }
}

/// Human-readable name of a wire error code (operator-facing output).
pub fn code_name(c: u16) -> &'static str {
    match c {
        code::UNKNOWN_MODEL => "unknown-model",
        code::WIDTH_MISMATCH => "width-mismatch",
        code::QUEUE_FULL => "queue-full",
        code::BACKEND_FAILED => "backend-failed",
        code::SHUTTING_DOWN => "shutting-down",
        code::BAD_FRAME => "bad-frame",
        code::OVERLOADED => "overloaded",
        _ => "unknown-code",
    }
}

/// One inference request: which model, and the packed feature row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InferRequestMsg {
    pub corr: u64,
    pub model: String,
    /// Logical feature width in bits; `words` holds `ceil(n_features/64)`
    /// LSB-first words with zero tail bits.
    pub n_features: u32,
    pub words: Vec<u64>,
}

/// The successful answer to an [`InferRequestMsg`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InferResponseMsg {
    pub corr: u64,
    /// Hot-swap generation of the backend that served the request.
    pub generation: u64,
    /// Argmax class.
    pub pred: u32,
    /// Signed per-class sums (length = the model's class count).
    pub sums: Vec<i32>,
}

/// A typed failure (request-scoped when `corr != 0`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ErrorMsg {
    pub corr: u64,
    pub code: u16,
    pub message: String,
}

/// Look up one served model's shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelQueryMsg {
    pub corr: u64,
    pub model: String,
}

/// The answer to a [`ModelQueryMsg`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelInfoMsg {
    pub corr: u64,
    pub model: String,
    pub n_features: u32,
    pub n_classes: u32,
    /// The model's current hot-swap generation.
    pub generation: u64,
}

// ---- little-endian payload primitives -----------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_i32(out: &mut Vec<u8>, v: i32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked payload reader. Every accessor fails with a message
/// instead of panicking — payload bytes are attacker-controlled.
struct Rd<'a> {
    b: &'a [u8],
}

impl<'a> Rd<'a> {
    fn new(b: &'a [u8]) -> Rd<'a> {
        Rd { b }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.b.len() < n {
            return Err(format!(
                "truncated payload: needed {n} more bytes, have {}",
                self.b.len()
            ));
        }
        let (head, rest) = self.b.split_at(n);
        self.b = rest;
        Ok(head)
    }

    fn u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i32(&mut self) -> Result<i32, String> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn name(&mut self) -> Result<String, String> {
        let len = self.u16()? as usize;
        if len > MAX_NAME_LEN {
            return Err(format!("model name length {len} exceeds the cap {MAX_NAME_LEN}"));
        }
        std::str::from_utf8(self.take(len)?)
            .map(str::to_string)
            .map_err(|_| "model name is not valid UTF-8".to_string())
    }

    fn done(self) -> Result<(), String> {
        if self.b.is_empty() {
            Ok(())
        } else {
            Err(format!("{} trailing bytes after the payload", self.b.len()))
        }
    }
}

fn put_name(out: &mut Vec<u8>, name: &str) {
    debug_assert!(name.len() <= MAX_NAME_LEN);
    put_u16(out, name.len() as u16);
    out.extend_from_slice(name.as_bytes());
}

impl InferRequestMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut out =
            Vec::with_capacity(8 + 2 + self.model.len() + 4 + self.words.len() * 8);
        put_u64(&mut out, self.corr);
        put_name(&mut out, &self.model);
        put_u32(&mut out, self.n_features);
        for &w in &self.words {
            put_u64(&mut out, w);
        }
        out
    }

    pub fn decode(payload: &[u8]) -> Result<InferRequestMsg, String> {
        let mut r = Rd::new(payload);
        let corr = r.u64()?;
        let model = r.name()?;
        let n_features = r.u32()?;
        if n_features > MAX_FEATURE_BITS {
            return Err(format!(
                "feature width {n_features} exceeds the cap {MAX_FEATURE_BITS}"
            ));
        }
        let n_words = words_for(n_features as usize);
        let mut words = Vec::with_capacity(n_words);
        for _ in 0..n_words {
            words.push(r.u64()?);
        }
        r.done()?;
        if let Some(&last) = words.last() {
            if last & !tail_mask(n_features as usize) != 0 {
                return Err(
                    "tail bits beyond the declared feature width must be zero".to_string()
                );
            }
        }
        Ok(InferRequestMsg { corr, model, n_features, words })
    }
}

impl InferResponseMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + 8 + 4 + 4 + self.sums.len() * 4);
        put_u64(&mut out, self.corr);
        put_u64(&mut out, self.generation);
        put_u32(&mut out, self.pred);
        put_u32(&mut out, self.sums.len() as u32);
        for &s in &self.sums {
            put_i32(&mut out, s);
        }
        out
    }

    pub fn decode(payload: &[u8]) -> Result<InferResponseMsg, String> {
        let mut r = Rd::new(payload);
        let corr = r.u64()?;
        let generation = r.u64()?;
        let pred = r.u32()?;
        let n_classes = r.u32()?;
        if n_classes > MAX_CLASSES {
            return Err(format!("class count {n_classes} exceeds the cap {MAX_CLASSES}"));
        }
        let mut sums = Vec::with_capacity(n_classes as usize);
        for _ in 0..n_classes {
            sums.push(r.i32()?);
        }
        r.done()?;
        Ok(InferResponseMsg { corr, generation, pred, sums })
    }
}

impl ErrorMsg {
    pub fn encode(&self) -> Vec<u8> {
        // Cap the message so one error can never approach the frame
        // payload limit (messages are diagnostics, not data).
        let msg = if self.message.len() > u16::MAX as usize {
            &self.message[..u16::MAX as usize]
        } else {
            &self.message[..]
        };
        let mut out = Vec::with_capacity(8 + 2 + 2 + msg.len());
        put_u64(&mut out, self.corr);
        put_u16(&mut out, self.code);
        put_u16(&mut out, msg.len() as u16);
        out.extend_from_slice(msg.as_bytes());
        out
    }

    pub fn decode(payload: &[u8]) -> Result<ErrorMsg, String> {
        let mut r = Rd::new(payload);
        let corr = r.u64()?;
        let code = r.u16()?;
        let len = r.u16()? as usize;
        let message = String::from_utf8_lossy(r.take(len)?).into_owned();
        r.done()?;
        Ok(ErrorMsg { corr, code, message })
    }
}

impl ModelQueryMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + 2 + self.model.len());
        put_u64(&mut out, self.corr);
        put_name(&mut out, &self.model);
        out
    }

    pub fn decode(payload: &[u8]) -> Result<ModelQueryMsg, String> {
        let mut r = Rd::new(payload);
        let corr = r.u64()?;
        let model = r.name()?;
        r.done()?;
        Ok(ModelQueryMsg { corr, model })
    }
}

impl ModelInfoMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + 2 + self.model.len() + 4 + 4 + 8);
        put_u64(&mut out, self.corr);
        put_name(&mut out, &self.model);
        put_u32(&mut out, self.n_features);
        put_u32(&mut out, self.n_classes);
        put_u64(&mut out, self.generation);
        out
    }

    pub fn decode(payload: &[u8]) -> Result<ModelInfoMsg, String> {
        let mut r = Rd::new(payload);
        let corr = r.u64()?;
        let model = r.name()?;
        let n_features = r.u32()?;
        let n_classes = r.u32()?;
        let generation = r.u64()?;
        r.done()?;
        Ok(ModelInfoMsg { corr, model, n_features, n_classes, generation })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    /// encode → decode ≡ id for feature widths straddling the word
    /// boundary (31 fits one partial word, 64 exactly one, 65 spills).
    #[test]
    fn infer_request_roundtrip_across_word_boundaries() {
        for &bits in &[31u32, 64, 65] {
            let mut rng = SplitMix64::new(bits as u64);
            for trial in 0..50 {
                let n_words = words_for(bits as usize);
                let mut words: Vec<u64> = (0..n_words).map(|_| rng.next_u64()).collect();
                if let Some(last) = words.last_mut() {
                    *last &= tail_mask(bits as usize);
                }
                let msg = InferRequestMsg {
                    corr: rng.next_u64(),
                    model: format!("tenant_{bits}"),
                    n_features: bits,
                    words,
                };
                let back = InferRequestMsg::decode(&msg.encode()).unwrap();
                assert_eq!(back, msg, "bits={bits} trial={trial}");
            }
        }
    }

    #[test]
    fn response_error_query_info_roundtrip() {
        let resp = InferResponseMsg {
            corr: 7,
            generation: 3,
            pred: 2,
            sums: vec![-5, 0, 17],
        };
        assert_eq!(InferResponseMsg::decode(&resp.encode()).unwrap(), resp);
        let err = ErrorMsg { corr: 9, code: code::QUEUE_FULL, message: "shed".into() };
        assert_eq!(ErrorMsg::decode(&err.encode()).unwrap(), err);
        let q = ModelQueryMsg { corr: 1, model: "mnist_c100".into() };
        assert_eq!(ModelQueryMsg::decode(&q.encode()).unwrap(), q);
        let info = ModelInfoMsg {
            corr: 1,
            model: "mnist_c100".into(),
            n_features: 784,
            n_classes: 10,
            generation: 4,
        };
        assert_eq!(ModelInfoMsg::decode(&info.encode()).unwrap(), info);
    }

    #[test]
    fn truncated_payloads_are_rejected_not_panicked() {
        let msg = InferRequestMsg {
            corr: 1,
            model: "m".into(),
            n_features: 65,
            words: vec![u64::MAX, 1],
        };
        let full = msg.encode();
        for cut in 0..full.len() {
            let err = InferRequestMsg::decode(&full[..cut]).unwrap_err();
            assert!(err.contains("truncated"), "cut={cut}: {err}");
        }
        // Trailing garbage is rejected too.
        let mut padded = full.clone();
        padded.push(0);
        assert!(InferRequestMsg::decode(&padded).unwrap_err().contains("trailing"));
    }

    #[test]
    fn hostile_lengths_are_capped_before_allocation() {
        // Feature width over the cap: rejected on the declared value,
        // before any word is read or allocated.
        let mut p = Vec::new();
        put_u64(&mut p, 1);
        put_name(&mut p, "m");
        put_u32(&mut p, MAX_FEATURE_BITS + 1);
        let err = InferRequestMsg::decode(&p).unwrap_err();
        assert!(err.contains("cap"), "{err}");

        // Name length over the cap.
        let mut p = Vec::new();
        put_u64(&mut p, 1);
        put_u16(&mut p, (MAX_NAME_LEN + 1) as u16);
        let err = ModelQueryMsg::decode(&p).unwrap_err();
        assert!(err.contains("cap"), "{err}");

        // Class count over the cap.
        let mut p = Vec::new();
        put_u64(&mut p, 1);
        put_u64(&mut p, 0);
        put_u32(&mut p, 0);
        put_u32(&mut p, MAX_CLASSES + 1);
        let err = InferResponseMsg::decode(&p).unwrap_err();
        assert!(err.contains("cap"), "{err}");
    }

    #[test]
    fn nonzero_tail_bits_are_refused() {
        // 31 declared bits but bit 31 set in the single word.
        let msg = InferRequestMsg {
            corr: 1,
            model: "m".into(),
            n_features: 31,
            words: vec![1u64 << 31],
        };
        let err = InferRequestMsg::decode(&msg.encode()).unwrap_err();
        assert!(err.contains("tail bits"), "{err}");
        // Exactly-at-the-boundary widths have no tail to violate.
        let ok = InferRequestMsg {
            corr: 1,
            model: "m".into(),
            n_features: 64,
            words: vec![u64::MAX],
        };
        assert!(InferRequestMsg::decode(&ok.encode()).is_ok());
    }

    #[test]
    fn infer_error_variants_map_to_distinct_codes() {
        let cases = [
            (InferError::UnknownModel { name: "g".into() }, code::UNKNOWN_MODEL),
            (InferError::WidthMismatch { got: 1, expected: 2 }, code::WIDTH_MISMATCH),
            (InferError::QueueFull { depth: 8, limit: 8 }, code::QUEUE_FULL),
            (InferError::BackendFailed("x".into()), code::BACKEND_FAILED),
            (InferError::ShuttingDown, code::SHUTTING_DOWN),
        ];
        let mut seen = std::collections::BTreeSet::new();
        for (e, expected) in cases {
            assert_eq!(error_code(&e), expected, "{e}");
            assert!(seen.insert(expected), "codes must be distinct");
            assert_ne!(code_name(expected), "unknown-code");
        }
        assert_eq!(code_name(code::BAD_FRAME), "bad-frame");
        assert_eq!(code_name(code::OVERLOADED), "overloaded");
        assert_eq!(code_name(999), "unknown-code");
    }

    #[test]
    fn kind_byte_roundtrip() {
        for k in [
            Kind::InferRequest,
            Kind::InferResponse,
            Kind::Error,
            Kind::ModelQuery,
            Kind::ModelInfo,
        ] {
            assert_eq!(Kind::from_u8(k.as_u8()), Some(k));
        }
        assert_eq!(Kind::from_u8(0), None);
        assert_eq!(Kind::from_u8(6), None);
    }
}
