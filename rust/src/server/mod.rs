//! L4 network serving: a dependency-free TCP front end over the
//! coordinator, plus a load-generation harness.
//!
//! The wire is a length-prefixed binary protocol (std-only — no tokio,
//! no serde): every frame is a 12-byte header (`b"TDPC"` magic, version,
//! kind, payload length) followed by one payload from
//! [`protocol`]. Feature rows travel *packed* (`u64` words, LSB-first,
//! zero tail bits — the request path's native currency), so a request
//! decodes straight into a [`crate::tm::BitVec64`] and enters
//! [`crate::coordinator::Coordinator::submit_packed_named`] without ever
//! materializing a bool slice.
//!
//! Layers inside this module:
//!
//! * [`protocol`] — payload encode/decode, error-code mapping
//!   ([`protocol::error_code`]) from typed
//!   [`crate::coordinator::InferError`]s;
//! * [`codec`] — frame framing over any `Read`/`Write`
//!   ([`codec::read_frame`] / [`codec::write_frame`]), with the declared
//!   payload length validated *before* allocation;
//! * `conn` (private) — per-connection reader/writer threads: pipelined
//!   decode-and-submit, replies streamed back in submission order via
//!   the shared [`crate::coordinator::await_reply`] helper;
//! * [`listener`] — the accept loop: connection cap and
//!   coordinator-saturation checks refuse connections with one
//!   `OVERLOADED` frame at accept time, shedding overload at the socket;
//! * [`client`] — a minimal blocking client (used by the loopback tests
//!   and the load generator; external clients only need the wire format);
//! * [`loadgen`] — open/closed-loop load harness writing
//!   `BENCH_serving.json` (schema `tdpc-bench-serving/v1`).

pub mod client;
pub mod codec;
mod conn;
pub mod listener;
pub mod loadgen;
pub mod protocol;

pub use client::{Client, ClientError};
pub use codec::{read_frame, write_frame, WireError};
pub use listener::{Server, ServerConfig};
pub use loadgen::{parse_mix, BurstShape, LoadReport, LoadgenConfig, Mode};
pub use protocol::{
    code, code_name, error_code, ErrorMsg, InferRequestMsg, InferResponseMsg, Kind,
    ModelInfoMsg, ModelQueryMsg, HEADER_LEN, MAGIC, MAX_PAYLOAD, VERSION,
};
