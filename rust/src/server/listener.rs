//! TCP accept loop with socket-level overload shedding.
//!
//! [`Server::start`] binds a listener and spawns one accept thread; each
//! accepted connection is served by [`super::conn::handle`] on its own
//! thread. Admission control composes with the coordinator's: when the
//! live-connection count reaches [`ServerConfig::max_conns`], or every
//! worker queue is at its bound ([`Coordinator::is_saturated`]), the
//! connection is *refused at accept* with one `OVERLOADED` error frame —
//! overload sheds at the socket before any request bytes are read,
//! instead of accumulating decoded requests in RAM.
//!
//! Shutdown choreography (race-free by ownership): the accept thread is
//! the *only* registrar of connections, holding the handler list as a
//! plain `Vec`. [`Server::shutdown`] sets the stop flag and nudges the
//! listener with a throwaway self-connection to unblock `accept`; the
//! accept thread then exits its loop, shuts down every live connection
//! socket (unblocking blocked readers), and joins every handler — no
//! handler can slip through between "snapshot the registry" and "stop",
//! because registration and teardown happen on the same thread.

use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::Coordinator;

use super::conn;

/// Listener-level admission knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Maximum concurrently served connections; the next accept past
    /// this is refused with `OVERLOADED`.
    pub max_conns: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_conns: 256 }
    }
}

/// One live connection as the accept thread tracks it.
struct Conn {
    stream: TcpStream,
    join: JoinHandle<()>,
}

/// Handle to a running TCP front end. Dropping it (or calling
/// [`Server::shutdown`]) stops accepting, closes every live connection,
/// and joins all serving threads. The coordinator itself is *not* shut
/// down — it is shared, and may outlive the listener.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an OS-assigned port) and
    /// start serving `coord` on it.
    pub fn start<A: ToSocketAddrs + std::fmt::Debug>(
        coord: Arc<Coordinator>,
        addr: A,
        cfg: ServerConfig,
    ) -> Result<Server> {
        let listener =
            TcpListener::bind(&addr).with_context(|| format!("binding {addr:?}"))?;
        let local = listener.local_addr().context("reading the bound address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("tdpc-accept".to_string())
                .spawn(move || accept_loop(listener, coord, cfg, stop))
                .context("spawning the accept thread")?
        };
        Ok(Server { addr: local, stop, accept: Some(accept) })
    }

    /// The actually bound address (the resolved port when the caller
    /// bound port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, close every live connection, and join all
    /// serving threads.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        let Some(accept) = self.accept.take() else { return };
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the blocking `accept` with a throwaway self-connection;
        // the accept thread re-checks the flag after every accept.
        let _ = TcpStream::connect(self.addr);
        let _ = accept.join();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(
    listener: TcpListener,
    coord: Arc<Coordinator>,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
) {
    // This thread is the sole owner of the connection registry, so
    // registration, reaping, and final teardown cannot race.
    let mut conns: Vec<Conn> = Vec::new();
    loop {
        let stream = match listener.accept() {
            Ok((stream, _peer)) => stream,
            Err(e) => {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                log::warn!("server: accept failed: {e}");
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if stop.load(Ordering::SeqCst) {
            // The shutdown nudge (or a late real client); drop it.
            break;
        }
        conns.retain(|c| !c.join.is_finished());
        if conns.len() >= cfg.max_conns {
            conn::refuse(stream, "connection limit reached; retry later");
            continue;
        }
        if coord.is_saturated() {
            conn::refuse(stream, "serving pool is saturated; retry later");
            continue;
        }
        let for_handler = match stream.try_clone() {
            Ok(s) => s,
            Err(e) => {
                log::warn!("server: could not clone an accepted stream: {e}");
                continue;
            }
        };
        let spawned = {
            let coord = coord.clone();
            std::thread::Builder::new()
                .name("tdpc-conn".to_string())
                .spawn(move || conn::handle(for_handler, coord))
        };
        match spawned {
            Ok(join) => conns.push(Conn { stream, join }),
            Err(e) => {
                log::warn!("server: could not spawn a connection handler: {e}");
                conn::refuse(stream, "server cannot spawn a handler; retry later");
            }
        }
    }
    // Teardown: force every live connection's reader off its socket,
    // then join the handlers (each drains its in-flight replies first).
    for c in &conns {
        let _ = c.stream.shutdown(std::net::Shutdown::Both);
    }
    for c in conns {
        let _ = c.join.join();
    }
}
