"""L1 correctness: Pallas kernel vs pure-jnp oracle.

Hypothesis sweeps shapes (batch, features, classes, clauses/class), include
densities, and dtypes; every case must match the oracle bit-exactly
(integer semantics), per the session's L1 testing contract.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

# Whole module needs the jax/Pallas toolchain; auto-skipped when absent
# (see conftest.py).
pytestmark = pytest.mark.requires_jax

from compile.kernels import clause_popcount as cp
from compile.kernels import ref


def make_case(rng, b, f, k, cpc, density):
    c = k * cpc
    xb = rng.integers(0, 2, (b, f)).astype(np.float32)
    lits = np.concatenate([xb, 1 - xb], axis=1)
    inc = (rng.random((c, 2 * f)) < density).astype(np.float32)
    ne = inc.any(axis=1).astype(np.float32)
    polf = np.tile(np.where(np.arange(cpc) % 2 == 0, 1, -1), k).astype(np.float32)
    P = ref.polarity_matrix(k, cpc, polf)
    return lits, inc, P, ne


def assert_matches_ref(lits, inc, P, ne):
    s_ref, f_ref = ref.clause_popcount_ref(
        jnp.array(lits), jnp.array(inc), jnp.array(P), jnp.array(ne)
    )
    s_ker, f_ker = cp.clause_popcount(
        jnp.array(lits), jnp.array(inc), jnp.array(P), jnp.array(ne)
    )
    np.testing.assert_array_equal(np.array(s_ref), np.array(s_ker))
    np.testing.assert_array_equal(np.array(f_ref), np.array(f_ker))


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 9),
    f=st.integers(1, 40),
    k=st.integers(2, 6),
    cpc=st.integers(2, 30),
    density=st.floats(0.0, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_random(b, f, k, cpc, density, seed):
    rng = np.random.default_rng(seed)
    assert_matches_ref(*make_case(rng, b, f, k, cpc, density))


@pytest.mark.parametrize(
    "b,f,k,cpc",
    [
        (1, 12, 3, 10),   # iris_c10 shape
        (32, 12, 3, 50),  # iris_c50 shape
        (1, 784, 10, 50), # mnist_c50 shape
        (32, 784, 10, 100),  # mnist_c100 shape
    ],
)
def test_kernel_paper_shapes(b, f, k, cpc):
    rng = np.random.default_rng(1234)
    assert_matches_ref(*make_case(rng, b, f, k, cpc, 0.15))


def test_kernel_tile_boundaries():
    """Clause counts straddling the 128-tile boundary."""
    rng = np.random.default_rng(7)
    for cpc in (42, 43, 64):  # k=3 -> C in {126, 129, 192}
        assert_matches_ref(*make_case(rng, 4, 20, 3, cpc, 0.2))


def test_empty_clauses_never_fire():
    """All-exclude clauses must output 0 and contribute 0 votes."""
    b, f, k, cpc = 4, 8, 2, 6
    lits = np.ones((b, 2 * f), dtype=np.float32)  # every literal true
    inc = np.zeros((k * cpc, 2 * f), dtype=np.float32)
    ne = inc.any(axis=1).astype(np.float32)
    polf = np.tile(np.where(np.arange(cpc) % 2 == 0, 1, -1), k).astype(np.float32)
    P = ref.polarity_matrix(k, cpc, polf)
    sums, fired = cp.clause_popcount(
        jnp.array(lits), jnp.array(inc), jnp.array(P), jnp.array(ne)
    )
    assert np.array(fired).sum() == 0
    assert np.array(sums).sum() == 0


def test_all_include_requires_all_ones():
    """A clause including every literal fires only on the all-ones input —
    and [x, ~x] literals are never all-ones, so it must never fire."""
    b, f = 3, 5
    xb = np.array([[1, 1, 1, 1, 1], [0, 0, 0, 0, 0], [1, 0, 1, 0, 1]], dtype=np.float32)
    lits = np.concatenate([xb, 1 - xb], axis=1)
    inc = np.ones((2, 2 * f), dtype=np.float32)
    ne = np.ones(2, dtype=np.float32)
    P = ref.polarity_matrix(1, 2, np.array([1, -1], dtype=np.float32))
    sums, fired = cp.clause_popcount(
        jnp.array(lits), jnp.array(inc), jnp.array(P), jnp.array(ne)
    )
    assert np.array(fired).sum() == 0


def test_sums_are_vote_differences():
    """Class sum == (#fired positive) - (#fired negative), per class."""
    rng = np.random.default_rng(99)
    lits, inc, P, ne = make_case(rng, 6, 16, 4, 12, 0.1)
    sums, fired = cp.clause_popcount(
        jnp.array(lits), jnp.array(inc), jnp.array(P), jnp.array(ne)
    )
    sums, fired = np.array(sums), np.array(fired)
    k, cpc = 4, 12
    pol = np.tile(np.where(np.arange(cpc) % 2 == 0, 1, -1), k)
    for bi in range(6):
        for ki in range(k):
            seg = slice(ki * cpc, (ki + 1) * cpc)
            assert sums[bi, ki] == int((fired[bi, seg] * pol[seg]).sum())


def test_vmem_report_fits_budget():
    """Every paper configuration must fit the 16 MiB VMEM budget."""
    for (k, cpc, f) in [(3, 10, 12), (3, 50, 12), (10, 50, 784), (10, 100, 784)]:
        rep = cp.vmem_report(k, cpc, f, 32)
        assert rep["fits_vmem"], rep
        assert rep["grid_steps"] >= 1
