"""L2 model tests: the jitted forward graph vs the oracle, shapes, and the
HLO lowering contract the Rust runtime depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_mod
from compile.kernels import ref
from compile.tm.automata import TsetlinMachine

# Whole module needs the jax/Pallas toolchain; auto-skipped when absent
# (see conftest.py).
pytestmark = pytest.mark.requires_jax


@pytest.fixture(scope="module")
def tiny_trained():
    """A quickly-trained tiny TM (deterministic)."""
    rng = np.random.default_rng(3)
    n, f, k = 120, 10, 3
    # Separable synthetic task: class = argmax over 3 disjoint feature groups.
    x = rng.integers(0, 2, (n, f)).astype(np.uint8)
    y = np.array([int(np.argmax([r[:3].sum(), r[3:6].sum(), r[6:9].sum()])) for r in x])
    tm = TsetlinMachine(k, f, 10, T=4, s=3.0, seed=5)
    from compile.tm.datasets import SplitMix64

    order = SplitMix64(11)
    for _ in range(25):
        tm.fit_epoch(x, y, order)
    return tm, x, y


def test_forward_matches_oracle(tiny_trained):
    tm, x, _ = tiny_trained
    params = model_mod.TmParams(tm.export())
    fwd = model_mod.make_forward(params)
    xb = x[:8].astype(np.float32)
    sums, fired, pred = jax.jit(fwd)(jnp.array(xb))
    p_ref, s_ref, f_ref = ref.tm_predict_ref(
        jnp.array(xb), jnp.array(params.include), jnp.array(params.polarity),
        jnp.array(params.nonempty),
    )
    np.testing.assert_array_equal(np.array(sums), np.array(s_ref))
    np.testing.assert_array_equal(np.array(fired), np.array(f_ref))
    np.testing.assert_array_equal(np.array(pred), np.array(p_ref))


def test_forward_shapes(tiny_trained):
    tm, x, _ = tiny_trained
    params = model_mod.TmParams(tm.export())
    fwd = model_mod.make_forward(params)
    for b in (1, 4, 32):
        xb = jnp.zeros((b, params.n_features), jnp.float32)
        sums, fired, pred = fwd(xb)
        assert sums.shape == (b, params.n_classes)
        assert fired.shape == (b, params.c_total)
        assert pred.shape == (b,)
        assert sums.dtype == jnp.int32 and pred.dtype == jnp.int32


def test_pallas_and_plain_paths_agree(tiny_trained):
    tm, x, _ = tiny_trained
    params = model_mod.TmParams(tm.export())
    xb = jnp.array(x[:6].astype(np.float32))
    s1, f1, p1 = model_mod.make_forward(params, use_pallas=True)(xb)
    s2, f2, p2 = model_mod.make_forward(params, use_pallas=False)(xb)
    np.testing.assert_array_equal(np.array(s1), np.array(s2))
    np.testing.assert_array_equal(np.array(f1), np.array(f2))
    np.testing.assert_array_equal(np.array(p1), np.array(p2))


def test_hlo_text_lowering(tiny_trained):
    tm, _, _ = tiny_trained
    params = model_mod.TmParams(tm.export())
    text = model_mod.lower_to_hlo_text(params, batch=2)
    # The contract the Rust loader depends on (aot_recipe): HLO text with a
    # 3-tuple root and the right parameter shape.
    assert "HloModule" in text
    assert f"f32[2,{params.n_features}]" in text
    assert "(s32[2,3]" in text or "s32[2,3]" in text


def test_model_prediction_accuracy(tiny_trained):
    tm, x, y = tiny_trained
    acc = tm.accuracy(x, y)
    assert acc > 0.8, f"tiny TM should learn the separable task, got {acc}"
