"""AOT pipeline tests: encoding round-trips and (when artifacts exist) the
integrity of the emitted manifest/golden files the Rust side consumes."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile.tm import train as train_mod

# compile.aot imports the jax lowering stack at module scope; auto-skipped
# when jax is absent (see conftest.py).
pytestmark = pytest.mark.requires_jax

ART = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))


def test_bitstring_roundtrip():
    rows = np.random.default_rng(0).integers(0, 2, (5, 40))
    enc = [aot.bits_to_str(r) for r in rows]
    dec = np.array([[int(c) for c in row] for row in enc])
    np.testing.assert_array_equal(rows, dec)


def test_encode_decode_model():
    doc = {
        "include": [[1, 0, 1], [0, 0, 0]],
        "polarity": [1, -1],
        "other": 42,
    }
    enc = aot.encode_model(doc)
    assert enc["include"] == ["101", "000"]
    dec = aot.decode_model(enc)
    assert dec["include"] == [[1, 0, 1], [0, 0, 0]]
    assert dec["other"] == 42


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@needs_artifacts
def test_manifest_covers_all_configs():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert set(manifest["models"]) == set(train_mod.CONFIGS)
    for name, entry in manifest["models"].items():
        for key in ("model", "golden", "test_data"):
            assert os.path.exists(os.path.join(ART, entry[key])), (name, key)
        for b, hlo in entry["hlo"].items():
            assert os.path.exists(os.path.join(ART, hlo)), (name, b)


@needs_artifacts
def test_golden_vectors_consistent_with_model():
    """Re-evaluate the golden inputs through the reference path and compare
    with the stored sums/preds — guards against model/golden drift."""
    import jax.numpy as jnp

    from compile import model as model_mod
    from compile.kernels import ref

    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    for name in ("iris_c10", "mnist_c50"):
        entry = manifest["models"][name]
        with open(os.path.join(ART, entry["model"])) as f:
            doc = aot.decode_model(json.load(f))
        with open(os.path.join(ART, entry["golden"])) as f:
            golden = json.load(f)
        params = model_mod.TmParams(doc)
        xb = np.array([[int(c) for c in row] for row in golden["inputs"]], dtype=np.float32)
        pred, sums, fired = ref.tm_predict_ref(
            jnp.array(xb), params.include, params.polarity, params.nonempty
        )
        np.testing.assert_array_equal(np.array(sums), np.array(golden["sums"]))
        np.testing.assert_array_equal(np.array(pred), np.array(golden["pred"]))


@needs_artifacts
def test_trained_accuracy_in_paper_range():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    for name, entry in manifest["models"].items():
        # Within a sensible band of the paper's Table I value (synthetic
        # MNIST is easier than real MNIST; see DESIGN.md §1).
        assert entry["accuracy"] >= entry["paper_accuracy"] - 8.0, name
        assert entry["accuracy"] <= 100.0


@needs_artifacts
def test_hlo_text_parseable_header():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    entry = manifest["models"]["iris_c10"]
    path = os.path.join(ART, entry["hlo"]["1"])
    text = open(path).read()
    assert text.startswith("HloModule"), "rust loader expects HLO text"
    assert "s32[1,3]" in text  # class sums output shape
