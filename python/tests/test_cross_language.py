"""Pins the shared Python↔Rust PRNG stream and dataset determinism.

`rust/src/util/rng.rs` re-implements SplitMix64; rust/tests/cross_language.rs
asserts the same constants below. If either side drifts, datasets would
silently diverge between the build path and the Rust substrate.
"""

import numpy as np

from compile.tm import booleanize, datasets
from compile.tm.datasets import SplitMix64

# Reference stream, also asserted on the Rust side.
PINNED_U64 = [
    6457827717110365317,
    3203168211198807973,
    9817491932198370423,
    4593380528125082431,
]


def test_splitmix_pinned_stream():
    r = SplitMix64(1234567)
    assert [r.next_u64() for _ in range(4)] == PINNED_U64


def test_f64_pinned():
    r = SplitMix64(0xDEAD)
    vals = [r.next_f64() for _ in range(3)]
    np.testing.assert_allclose(
        vals,
        [0.13048625271529091, 0.65448148162553266, 0.017882184589982808],
        rtol=0,
        atol=0,
    )


def test_gauss_pinned():
    r = SplitMix64(42)
    vals = [r.next_gauss() for _ in range(3)]
    np.testing.assert_allclose(
        vals,
        [0.41471975043153059, -0.89188621362775633, 1.7295930879374024],
        rtol=1e-15,
    )


def test_iris_deterministic():
    x1, y1 = datasets.iris()
    x2, y2 = datasets.iris()
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.shape == (150, 4)
    assert list(np.bincount(y1)) == [50, 50, 50]


def test_mnist_deterministic_and_balanced():
    x1, y1, xt1, yt1 = datasets.mnist(n_train=60, n_test=30)
    x2, y2, _, _ = datasets.mnist(n_train=60, n_test=30)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.shape == (60, 28, 28)
    assert list(np.bincount(y1)) == [6] * 10
    # Booleanization: reasonable ink coverage after threshold-75.
    xb = booleanize.booleanize_mnist(x1)
    assert 0.03 < xb.mean() < 0.4


def test_iris_split_is_stratified_and_disjoint():
    x, y = datasets.iris()
    x_tr, y_tr, x_te, y_te = datasets.train_test_split_iris(x, y)
    assert len(y_te) == 30 and len(y_tr) == 120
    assert list(np.bincount(y_te)) == [10, 10, 10]
    assert list(np.bincount(y_tr)) == [40, 40, 40]
