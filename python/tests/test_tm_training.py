"""TM training substrate tests: automata semantics, feedback behaviour,
Booleanization, and the export format contract."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.tm import booleanize
from compile.tm.automata import TsetlinMachine
from compile.tm.datasets import SplitMix64


def make_tm(**kw):
    kw.setdefault("n_classes", 2)
    kw.setdefault("n_features", 4)
    kw.setdefault("clauses", 6)
    kw.setdefault("T", 4)
    kw.setdefault("s", 3.0)
    return TsetlinMachine(kw.pop("n_classes"), kw.pop("n_features"), kw.pop("clauses"), **kw)


def test_initial_state_all_excluded():
    tm = make_tm()
    assert tm.includes().sum() == 0
    # Empty clauses output 0 at inference but 1 during training (bootstrap).
    lits = np.ones(8, dtype=np.uint8)
    assert tm.clause_outputs(lits, training=False).sum() == 0
    assert tm.clause_outputs(lits, training=True).sum() == tm.clauses * tm.n_classes


def test_polarity_alternates():
    tm = make_tm(clauses=8)
    assert list(tm.polarity[:4]) == [1, -1, 1, -1]


def test_state_bounds_respected():
    tm = make_tm()
    rng = SplitMix64(3)
    x = np.random.default_rng(0).integers(0, 2, (50, 4)).astype(np.uint8)
    y = np.random.default_rng(1).integers(0, 2, 50)
    for _ in range(5):
        tm.fit_epoch(x, y, rng)
    assert tm.state.min() >= 1
    assert tm.state.max() <= 2 * tm.n_states


def test_type_ii_only_includes_zero_literals():
    tm = make_tm()
    # Force a fired clause and apply Type II: only 0-literals may move
    # toward inclusion, and by exactly one step.
    before = tm.state.copy()
    lits = np.array([1, 0, 1, 0, 0, 1, 0, 1], dtype=np.uint8)
    clause_out = np.ones(tm.clauses, dtype=np.uint8)
    mask = np.ones(tm.clauses, dtype=bool)
    tm._type_ii(0, mask, clause_out, lits)
    delta = tm.state[0].astype(int) - before[0].astype(int)
    assert set(np.unique(delta)) <= {0, 1}
    # Only positions where the literal is 0 moved.
    moved = np.where(delta.sum(axis=0) > 0)[0]
    assert all(lits[i] == 0 for i in moved)


def test_learns_xor_like_task():
    # XOR of two Booleans — requires both polarities to cooperate.
    rng = np.random.default_rng(9)
    x = rng.integers(0, 2, (200, 2)).astype(np.uint8)
    y = (x[:, 0] ^ x[:, 1]).astype(np.int64)
    tm = TsetlinMachine(2, 2, 10, T=4, s=3.0, seed=2)
    order = SplitMix64(5)
    for _ in range(40):
        tm.fit_epoch(x, y, order)
    assert tm.accuracy(x, y) > 0.95


def test_export_format():
    tm = make_tm(n_classes=3, clauses=4)
    doc = tm.export()
    assert doc["n_classes"] == 3
    assert len(doc["include"]) == 12
    assert len(doc["include"][0]) == 8
    assert len(doc["polarity"]) == 12
    assert doc["polarity"][:4] == [1, -1, 1, -1]
    assert all(v in (0, 1) for v in doc["nonempty"])


@settings(max_examples=20, deadline=None)
@given(
    vals=st.lists(st.floats(0.0, 10.0), min_size=12, max_size=60),
    n_bins=st.integers(2, 5),
)
def test_quantile_binning_one_hot(vals, n_bins):
    col = np.array(vals).reshape(-1, 1)
    edges = booleanize.fit_iris_binning(col, n_bins)
    xb = booleanize.booleanize_iris(col, edges)
    assert xb.shape == (len(vals), n_bins)
    # Exactly one bin active per sample.
    np.testing.assert_array_equal(xb.sum(axis=1), np.ones(len(vals)))


def test_mnist_threshold():
    img = np.zeros((1, 28, 28), dtype=np.uint8)
    img[0, 3, 4] = 75   # at threshold: not above → 0
    img[0, 5, 6] = 76   # above → 1
    xb = booleanize.booleanize_mnist(img)
    assert xb[0, 3 * 28 + 4] == 0
    assert xb[0, 5 * 28 + 6] == 1
    assert xb.sum() == 1


def test_literals_augmentation():
    xb = np.array([[1, 0, 1]], dtype=np.uint8)
    lits = booleanize.to_literals(xb)
    np.testing.assert_array_equal(lits, [[1, 0, 1, 0, 1, 0]])
