"""Shared test configuration: jax-dependent tests auto-skip when jax is
unavailable (the CI python job installs only numpy + test deps — the
PJRT/Pallas toolchain is a heavyweight optional extra).

Modules that import jax at module scope declare
``pytestmark = pytest.mark.requires_jax`` and are excluded from
collection entirely when jax is missing, so collection never dies on an
ImportError; any individually marked test in an importable module is
skipped with a reason instead.
"""

import importlib.util

import pytest

HAS_JAX = importlib.util.find_spec("jax") is not None

# Modules whose top-level imports require jax; skipping them at collection
# time avoids import errors before markers can even apply.
_JAX_MODULES = ["test_aot.py", "test_kernel.py", "test_model.py"]

collect_ignore = [] if HAS_JAX else list(_JAX_MODULES)


def pytest_collection_modifyitems(config, items):
    if HAS_JAX:
        return
    skip = pytest.mark.skip(reason="jax is not installed (pip install -e 'python[jax]')")
    for item in items:
        if "requires_jax" in item.keywords:
            item.add_marker(skip)
