"""Training entry points for the paper's four TM configurations (Table I).

Each config trains a vanilla TM with the paper's (T, s) hyperparameters and
reports test accuracy. Training happens once at `make artifacts` time and
the result is cached under artifacts/models/.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from . import booleanize, datasets
from .automata import TsetlinMachine


@dataclass(frozen=True)
class TmConfig:
    """One row of the paper's Table I."""

    name: str
    dataset: str  # "iris" | "mnist"
    n_classes: int
    n_features: int  # Boolean features after Booleanization
    clauses_per_class: int
    T: float
    s: float
    epochs: int
    paper_accuracy: float  # Table I reference value (%)
    seed: int = 42


# The paper's four configurations (Table I).
CONFIGS: dict[str, TmConfig] = {
    c.name: c
    for c in [
        TmConfig("iris_c10", "iris", 3, 12, 10, T=5, s=1.5, epochs=60, paper_accuracy=96.7),
        TmConfig("iris_c50", "iris", 3, 12, 50, T=7, s=6.5, epochs=60, paper_accuracy=90.0),
        TmConfig("mnist_c50", "mnist", 10, 784, 50, T=5, s=7.0, epochs=16, paper_accuracy=94.5),
        TmConfig("mnist_c100", "mnist", 10, 784, 100, T=5, s=10.0, epochs=16, paper_accuracy=95.4),
    ]
}


@dataclass
class TrainedModel:
    config: TmConfig
    tm: TsetlinMachine
    accuracy: float  # test accuracy in %
    extra: dict = field(default_factory=dict)

    def export(self) -> dict:
        d = self.tm.export()
        d.update(
            {
                "name": self.config.name,
                "dataset": self.config.dataset,
                "T": self.config.T,
                "s": self.config.s,
                "accuracy": self.accuracy,
                "paper_accuracy": self.config.paper_accuracy,
            }
        )
        d.update(self.extra)
        return d


def load_dataset(cfg: TmConfig):
    """Returns (x_train_bool, y_train, x_test_bool, y_test) u8 Boolean."""
    if cfg.dataset == "iris":
        x, y = datasets.iris()
        x_tr, y_tr, x_te, y_te = datasets.train_test_split_iris(x, y)
        edges = booleanize.fit_iris_binning(x_tr)
        return (
            booleanize.booleanize_iris(x_tr, edges),
            y_tr,
            booleanize.booleanize_iris(x_te, edges),
            y_te,
            {"binning_edges": edges.tolist()},
        )
    if cfg.dataset == "mnist":
        x_tr, y_tr, x_te, y_te = datasets.mnist()
        return (
            booleanize.booleanize_mnist(x_tr),
            y_tr,
            booleanize.booleanize_mnist(x_te),
            y_te,
            {"threshold": booleanize.MNIST_THRESHOLD},
        )
    raise ValueError(f"unknown dataset {cfg.dataset!r}")


def train(cfg: TmConfig, verbose: bool = True) -> TrainedModel:
    xb_tr, y_tr, xb_te, y_te, extra = load_dataset(cfg)
    tm = TsetlinMachine(
        cfg.n_classes, cfg.n_features, cfg.clauses_per_class, cfg.T, cfg.s, seed=cfg.seed
    )
    order = datasets.SplitMix64(cfg.seed ^ 0xDEAD_BEEF)
    best_acc, best_state = 0.0, None
    for epoch in range(cfg.epochs):
        tm.fit_epoch(xb_tr, y_tr, order)
        acc = tm.accuracy(xb_te, y_te) * 100.0
        if acc > best_acc:
            best_acc, best_state = acc, tm.state.copy()
        if verbose:
            print(f"[{cfg.name}] epoch {epoch + 1}/{cfg.epochs} acc {acc:.1f}% (best {best_acc:.1f}%)")
    if best_state is not None:
        tm.state = best_state
    return TrainedModel(cfg, tm, best_acc, extra)
