"""Tsetlin Machine training substrate (build-time only)."""
from . import automata, booleanize, datasets, train  # noqa: F401
