"""Booleanization (paper §IV-B, following Rahman et al. [22]).

* Iris: each raw feature is quantile-binned into 3 bins and one-hot encoded
  as 3 Boolean features -> 12 Boolean features total.
* MNIST: every grayscale pixel is thresholded at 75 -> 784 Boolean features.
"""

from __future__ import annotations

import numpy as np

MNIST_THRESHOLD = 75


def quantile_edges(col: np.ndarray, n_bins: int) -> np.ndarray:
    """Bin edges at the (1/n .. (n-1)/n) quantiles of the training column."""
    qs = [(i + 1) / n_bins for i in range(n_bins - 1)]
    return np.quantile(col, qs)


def fit_iris_binning(x_train: np.ndarray, n_bins: int = 3) -> np.ndarray:
    """Per-feature quantile edges, shape (n_features, n_bins-1)."""
    return np.stack([quantile_edges(x_train[:, f], n_bins) for f in range(x_train.shape[1])])


def booleanize_iris(x: np.ndarray, edges: np.ndarray) -> np.ndarray:
    """One-hot bin membership: (n, F) raw -> (n, F * n_bins) Boolean u8."""
    n, nf = x.shape
    n_bins = edges.shape[1] + 1
    out = np.zeros((n, nf * n_bins), dtype=np.uint8)
    for f in range(nf):
        bins = np.digitize(x[:, f], edges[f])  # 0..n_bins-1
        out[np.arange(n), f * n_bins + bins] = 1
    return out


def booleanize_mnist(x: np.ndarray, threshold: int = MNIST_THRESHOLD) -> np.ndarray:
    """(n, 28, 28) u8 grayscale -> (n, 784) Boolean u8."""
    return (x.reshape(x.shape[0], -1) > threshold).astype(np.uint8)


def to_literals(x_bool: np.ndarray) -> np.ndarray:
    """Augment Boolean features with their negations: (n, F) -> (n, 2F).

    Literal layout is [x_0..x_{F-1}, ~x_0..~x_{F-1}] — the same convention
    used by the Pallas kernel, the HLO model, and the Rust clause evaluator.
    """
    return np.concatenate([x_bool, 1 - x_bool], axis=1).astype(np.uint8)
