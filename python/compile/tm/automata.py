"""Vanilla Tsetlin Machine (Granmo 2018 [1]) — vectorized numpy trainer.

One TM per class (paper Fig. 1a). Each class owns `clauses` clauses; even
indices have positive polarity (+1 vote), odd indices negative (-1). Each
clause is a team of Tsetlin automata, one per literal (x and ~x for every
Boolean feature). An automaton with state > N *includes* its literal in the
clause conjunction.

Training uses the classic two-feedback scheme:

* Type I (recognize / combat false negatives): drives clauses of the target
  polarity toward matching the sample; rewards included literals that are 1
  with prob (s-1)/s, erodes everything else with prob 1/s.
* Type II (discriminate / combat false positives): when a clause of the
  opposing role fires, includes 0-literals to break the match.

The update is per-sample (as in the paper's reference implementations) but
vectorized over (clauses x literals), which is fast enough for the build
path; inference afterwards is pure tensor algebra (see kernels/ref.py).
"""

from __future__ import annotations

import numpy as np

from .datasets import SplitMix64


class TsetlinMachine:
    """Multi-class vanilla TM with per-class clause teams.

    Parameters mirror the paper's Table I: `clauses` is *per class*; (T, s)
    are the voting target and specificity hyperparameters.
    """

    def __init__(self, n_classes: int, n_features: int, clauses: int, T: float, s: float,
                 n_states: int = 128, seed: int = 42):
        self.n_classes = n_classes
        self.n_features = n_features
        self.n_literals = 2 * n_features
        self.clauses = clauses
        self.T = float(T)
        self.s = float(s)
        self.n_states = n_states
        self.rng = np.random.default_rng(seed)
        # State in 1..2N; include iff state > N. Start just on the exclude
        # side of the boundary so clauses begin empty but mobile.
        self.state = np.full((n_classes, clauses, self.n_literals), n_states, dtype=np.int16)
        # Polarity: even clause index -> +1, odd -> -1 (paper Fig. 1a).
        self.polarity = np.where(np.arange(clauses) % 2 == 0, 1, -1).astype(np.int32)

    # -- inference ---------------------------------------------------------

    def includes(self) -> np.ndarray:
        """(classes, clauses, literals) u8 include mask."""
        return (self.state > self.n_states).astype(np.uint8)

    def clause_outputs(self, literals: np.ndarray, training: bool = False) -> np.ndarray:
        """Evaluate all clauses on one sample.

        literals: (n_literals,) u8. Returns (classes, clauses) u8.
        During inference, empty clauses output 0 (standard TM rule, and what
        the hardware does: an all-exclude clause never asserts). During
        training they output 1 so Type I feedback can bootstrap them.
        """
        inc = self.includes()
        # violated iff some included literal is 0.
        violations = np.einsum("kcl,l->kc", inc.astype(np.int32), (1 - literals).astype(np.int32))
        out = (violations == 0).astype(np.uint8)
        if not training:
            nonempty = inc.any(axis=2)
            out &= nonempty
        return out

    def class_sums(self, literals: np.ndarray, training: bool = False) -> np.ndarray:
        out = self.clause_outputs(literals, training=training).astype(np.int32)
        return (out * self.polarity[None, :]).sum(axis=1)

    def predict(self, X_bool: np.ndarray) -> np.ndarray:
        """Batch prediction. X_bool: (n, n_features) u8 -> (n,) labels."""
        lits = np.concatenate([X_bool, 1 - X_bool], axis=1).astype(np.int32)
        inc = self.includes().reshape(-1, self.n_literals).astype(np.int32)
        viol = inc @ (1 - lits).T  # (classes*clauses, n)
        fired = (viol == 0).astype(np.int32)
        nonempty = inc.any(axis=1).astype(np.int32)
        fired *= nonempty[:, None]
        fired = fired.reshape(self.n_classes, self.clauses, -1)
        sums = (fired * self.polarity[None, :, None]).sum(axis=1)  # (classes, n)
        return sums.argmax(axis=0)

    # -- training ----------------------------------------------------------

    def _type_i(self, cls: int, clause_mask: np.ndarray, clause_out: np.ndarray,
                literals: np.ndarray):
        """Type I feedback to the selected clauses of class `cls`."""
        s = self.s
        st = self.state[cls]
        n_c, n_l = st.shape
        rand = self.rng.random((n_c, n_l))
        lit = literals[None, :].astype(bool)
        sel = clause_mask[:, None]
        fired = clause_out[:, None].astype(bool)

        # Clause fired: literal==1 -> reinforce include w.p. (s-1)/s;
        #               literal==0 -> erode (toward exclude) w.p. 1/s.
        reinforce = sel & fired & lit & (rand <= (s - 1.0) / s)
        erode_fired = sel & fired & ~lit & (rand <= 1.0 / s)
        # Clause not fired: everything erodes w.p. 1/s.
        erode_idle = sel & ~fired & (rand <= 1.0 / s)

        st += reinforce.astype(np.int16)
        st -= (erode_fired | erode_idle).astype(np.int16)
        np.clip(st, 1, 2 * self.n_states, out=st)

    def _type_ii(self, cls: int, clause_mask: np.ndarray, clause_out: np.ndarray,
                 literals: np.ndarray):
        """Type II feedback: include 0-literals of fired clauses (one step)."""
        st = self.state[cls]
        lit = literals[None, :].astype(bool)
        sel = clause_mask[:, None] & clause_out[:, None].astype(bool)
        excluded = st <= self.n_states
        bump = sel & ~lit & excluded
        st += bump.astype(np.int16)

    def update(self, literals: np.ndarray, target: int):
        """One sample update (target class + one random negative class)."""
        T = self.T
        # Target class.
        out_t = self.clause_outputs(literals, training=True)[target]
        sum_t = float(np.clip((out_t.astype(np.int32) * self.polarity).sum(), -T, T))
        p_t = (T - sum_t) / (2 * T)
        feedback = self.rng.random(self.clauses) <= p_t
        pos = self.polarity == 1
        self._type_i(target, feedback & pos, out_t, literals)
        self._type_ii(target, feedback & ~pos, out_t, literals)

        # One random negative class (standard multiclass TM scheme).
        if self.n_classes > 1:
            neg = int(self.rng.integers(self.n_classes - 1))
            if neg >= target:
                neg += 1
            out_n = self.clause_outputs(literals, training=True)[neg]
            sum_n = float(np.clip((out_n.astype(np.int32) * self.polarity).sum(), -T, T))
            p_n = (T + sum_n) / (2 * T)
            feedback = self.rng.random(self.clauses) <= p_n
            self._type_i(neg, feedback & ~pos, out_n, literals)
            self._type_ii(neg, feedback & pos, out_n, literals)

    def fit_epoch(self, X_bool: np.ndarray, y: np.ndarray, order_rng: SplitMix64):
        n = X_bool.shape[0]
        idx = list(range(n))
        for i in range(n - 1, 0, -1):
            j = order_rng.next_below(i + 1)
            idx[i], idx[j] = idx[j], idx[i]
        lits_all = np.concatenate([X_bool, 1 - X_bool], axis=1).astype(np.uint8)
        for i in idx:
            self.update(lits_all[i], int(y[i]))

    def accuracy(self, X_bool: np.ndarray, y: np.ndarray) -> float:
        return float((self.predict(X_bool) == y).mean())

    # -- export ------------------------------------------------------------

    def export(self) -> dict:
        """Model in the interchange format shared with HLO/Rust.

        Clause axis is flattened class-major: clause index g = k*clauses + j.
        """
        inc = self.includes().reshape(self.n_classes * self.clauses, self.n_literals)
        nonempty = inc.any(axis=1).astype(np.uint8)
        pol = np.tile(self.polarity, self.n_classes)
        return {
            "n_classes": self.n_classes,
            "n_features": self.n_features,
            "clauses_per_class": self.clauses,
            "include": inc.tolist(),
            "polarity": pol.tolist(),
            "nonempty": nonempty.tolist(),
        }
