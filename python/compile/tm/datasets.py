"""Datasets for the TM case study (paper §IV-B).

Two datasets, matching the paper's Table I:

* **Iris** — 3 classes, 4 raw features. The UCI CSV is not available in this
  offline environment, so we synthesize 150 samples (50/class) from the
  published per-class means / standard deviations / feature correlations of
  Fisher's data. The quantile-binned Booleanization (3 bins per feature,
  one-hot -> 12 Boolean features) and the TM on top behave identically to
  the real data for the purposes of the paper's experiments (class-sum
  margins, PDL delay tuning). Documented in DESIGN.md §1.

* **MNIST** — 10 classes, 28x28 grayscale. Real MNIST cannot be downloaded
  here, so we generate a *procedural* digit dataset: stroke-rendered digit
  skeletons + random affine jitter + speckle noise, thresholded at 75
  exactly like the paper. Same shapes (784 Boolean features), same
  Booleanization code path, and TM accuracies in the paper's range.

Both generators are deterministic given a seed; the Rust side regenerates
identical data from the same splitmix64 stream (see rust/src/tm/datasets.rs
and test_cross_language.py).
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# Deterministic PRNG shared with the Rust side.
# ---------------------------------------------------------------------------


class SplitMix64:
    """splitmix64 — tiny, seedable, and trivially re-implementable in Rust.

    We intentionally avoid np.random so that the Rust substrate can
    regenerate bit-identical datasets without a numpy dependency.
    """

    MASK = (1 << 64) - 1

    def __init__(self, seed: int):
        self.state = seed & self.MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & self.MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & self.MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & self.MASK
        return (z ^ (z >> 31)) & self.MASK

    def next_f64(self) -> float:
        """Uniform in [0, 1) with 53-bit resolution."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def next_gauss(self) -> float:
        """Standard normal via Box-Muller (always the cosine branch, one
        fresh pair of uniforms per call, so Rust can mirror call-for-call)."""
        u1 = self.next_f64()
        u2 = self.next_f64()
        while u1 <= 1e-12:
            u1 = self.next_f64()
            u2 = self.next_f64()
        return float(np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2))

    def next_below(self, n: int) -> int:
        """Unbiased-enough modulo draw (n << 2^64)."""
        return self.next_u64() % n


# ---------------------------------------------------------------------------
# Iris (synthesized from the published class statistics).
# ---------------------------------------------------------------------------

# Per-class feature means and standard deviations of Fisher's Iris data
# (sepal length, sepal width, petal length, petal width), from the UCI
# summary statistics.
IRIS_MEANS = {
    0: [5.006, 3.428, 1.462, 0.246],  # setosa
    1: [5.936, 2.770, 4.260, 1.326],  # versicolor
    2: [6.588, 2.974, 5.552, 2.026],  # virginica
}
IRIS_STDS = {
    0: [0.352, 0.379, 0.174, 0.105],
    1: [0.516, 0.314, 0.470, 0.198],
    2: [0.636, 0.322, 0.552, 0.275],
}
# Within-class feature correlation (roughly shared across classes in the
# real data; sepal length correlates with petal length etc.).
IRIS_CORR = np.array(
    [
        [1.00, 0.50, 0.75, 0.55],
        [0.50, 1.00, 0.40, 0.45],
        [0.75, 0.40, 1.00, 0.65],
        [0.55, 0.45, 0.65, 1.00],
    ]
)

IRIS_SEED = 0x1B15_0001


def iris(seed: int = IRIS_SEED):
    """150 samples (50/class), 4 features. Returns (X f64[150,4], y i64[150])."""
    rng = SplitMix64(seed)
    chol = np.linalg.cholesky(IRIS_CORR)
    xs, ys = [], []
    for cls in range(3):
        mu = np.array(IRIS_MEANS[cls])
        sd = np.array(IRIS_STDS[cls])
        for _ in range(50):
            z = np.array([rng.next_gauss() for _ in range(4)])
            x = mu + sd * (chol @ z)
            # Features are physically positive and recorded to 1 decimal.
            x = np.maximum(np.round(x, 1), 0.1)
            xs.append(x)
            ys.append(cls)
    return np.array(xs), np.array(ys, dtype=np.int64)


# ---------------------------------------------------------------------------
# Synthetic MNIST: procedural stroke-rendered digits.
# ---------------------------------------------------------------------------

# Digit skeletons as polylines on a 16x16 design grid, scaled into 28x28.
# Hand-drawn to be visually digit-like; class separability (not human
# aesthetics) is what matters for the TM experiments.
_DIGIT_STROKES = {
    0: [[(4, 3), (11, 3), (13, 6), (13, 10), (11, 13), (4, 13), (2, 10), (2, 6), (4, 3)]],
    1: [[(6, 5), (8, 3), (8, 13)], [(5, 13), (11, 13)]],
    2: [[(3, 5), (5, 3), (10, 3), (12, 5), (12, 7), (3, 13), (13, 13)]],
    3: [[(3, 3), (12, 3), (8, 7), (12, 10), (10, 13), (3, 13)], [(8, 7), (12, 7)]],
    4: [[(10, 13), (10, 3), (3, 10), (13, 10)]],
    5: [[(12, 3), (4, 3), (4, 8), (10, 8), (12, 10), (10, 13), (3, 13)]],
    6: [[(11, 3), (5, 3), (3, 7), (3, 11), (5, 13), (10, 13), (12, 11), (10, 8), (4, 8)]],
    7: [[(3, 3), (13, 3), (7, 13)], [(5, 8), (11, 8)]],
    8: [[(8, 3), (12, 5), (8, 8), (4, 5), (8, 3)], [(8, 8), (12, 11), (8, 13), (4, 11), (8, 8)]],
    9: [[(12, 8), (6, 8), (4, 5), (6, 3), (11, 3), (12, 5), (12, 10), (10, 13), (5, 13)]],
}

MNIST_SEED = 0x3A57_0002


def _draw_stroke(img: np.ndarray, p0, p1, thickness: float):
    """Rasterize a line segment with the given thickness onto a 28x28 canvas
    using integer supersampling (no antialiasing libs available)."""
    (x0, y0), (x1, y1) = p0, p1
    steps = max(int(4 * max(abs(x1 - x0), abs(y1 - y0))) + 1, 2)
    for i in range(steps):
        t = i / (steps - 1)
        cx = x0 + t * (x1 - x0)
        cy = y0 + t * (y1 - y0)
        r = thickness / 2.0
        lo_x, hi_x = int(np.floor(cx - r)), int(np.ceil(cx + r))
        lo_y, hi_y = int(np.floor(cy - r)), int(np.ceil(cy + r))
        for px in range(lo_x, hi_x + 1):
            for py in range(lo_y, hi_y + 1):
                if 0 <= px < 28 and 0 <= py < 28:
                    d2 = (px - cx) ** 2 + (py - cy) ** 2
                    if d2 <= r * r:
                        img[py, px] = 255.0


def render_digit(digit: int, rng: SplitMix64) -> np.ndarray:
    """Render one 28x28 grayscale digit with random affine jitter + noise."""
    # Random affine: scale, rotation, translation. Real MNIST digits are
    # centred by centre-of-mass, so translation jitter is kept small; most
    # of the within-class variation comes from rotation/shear/thickness.
    scale = 1.35 + 0.14 * (rng.next_f64() - 0.5)  # design grid 16 -> ~22 px
    theta = 0.14 * (rng.next_f64() - 0.5)  # ~±4 degrees
    dx = 4.4 + 1.2 * rng.next_f64()
    dy = 4.4 + 1.2 * rng.next_f64()
    shear = 0.12 * (rng.next_f64() - 0.5)
    thickness = 1.7 + 0.7 * rng.next_f64()
    ct, st = np.cos(theta), np.sin(theta)

    def xf(p):
        x, y = p
        x, y = x + shear * y, y
        xr = ct * x - st * y
        yr = st * x + ct * y
        return (scale * xr + dx, scale * yr + dy)

    img = np.zeros((28, 28), dtype=np.float64)
    for stroke in _DIGIT_STROKES[digit]:
        pts = [xf(p) for p in stroke]
        for a, b in zip(pts[:-1], pts[1:]):
            _draw_stroke(img, a, b, thickness * scale / 1.35)

    # Speckle noise: a few random bright/dark pixels + low background haze.
    n_speckle = 6 + rng.next_below(10)
    for _ in range(n_speckle):
        px, py = rng.next_below(28), rng.next_below(28)
        img[py, px] = 255.0 * rng.next_f64()
    # Erosion-style dropout on the stroke itself.
    n_drop = rng.next_below(14)
    on = np.argwhere(img > 128)
    for _ in range(n_drop):
        if len(on) == 0:
            break
        k = rng.next_below(len(on))
        py, px = on[k]
        img[py, px] = 255.0 * 0.2 * rng.next_f64()
    return img


def mnist(n_train: int = 2000, n_test: int = 500, seed: int = MNIST_SEED):
    """Procedural MNIST-like dataset.

    Returns (x_train u8[n,28,28], y_train, x_test, y_test); labels are drawn
    round-robin so classes are balanced.
    """
    rng = SplitMix64(seed)
    def gen(n):
        xs = np.zeros((n, 28, 28), dtype=np.uint8)
        ys = np.zeros(n, dtype=np.int64)
        for i in range(n):
            d = i % 10
            xs[i] = np.clip(render_digit(d, rng), 0, 255).astype(np.uint8)
            ys[i] = d
        return xs, ys

    x_tr, y_tr = gen(n_train)
    x_te, y_te = gen(n_test)
    return x_tr, y_tr, x_te, y_te


def train_test_split_iris(x, y, test_frac: float = 0.2, seed: int = 7):
    """Deterministic stratified split (same algorithm mirrored in Rust)."""
    rng = SplitMix64(seed)
    train_idx, test_idx = [], []
    for cls in np.unique(y):
        idx = list(np.where(y == cls)[0])
        # Fisher-Yates with our PRNG.
        for i in range(len(idx) - 1, 0, -1):
            j = rng.next_below(i + 1)
            idx[i], idx[j] = idx[j], idx[i]
        k = int(round(len(idx) * test_frac))
        test_idx.extend(idx[:k])
        train_idx.extend(idx[k:])
    train_idx, test_idx = np.array(sorted(train_idx)), np.array(sorted(test_idx))
    return x[train_idx], y[train_idx], x[test_idx], y[test_idx]
