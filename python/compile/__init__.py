"""Build-time compile path: TM training, Pallas kernels, AOT lowering."""
