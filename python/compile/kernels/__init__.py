"""L1 Pallas kernels + pure-jnp oracles (build-time only)."""
from . import clause_popcount, ref  # noqa: F401
