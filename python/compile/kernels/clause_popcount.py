"""L1 Pallas kernel: fused clause evaluation + signed popcount.

Hardware adaptation (DESIGN.md §2): the paper implements clause AND-trees in
FPGA LUTs and the popcount as a programmable delay line. On TPU the same
insight — popcount/argmax only need *relative* magnitudes, so pick the
representation the hardware is natively fast at — maps both stages onto the
MXU as two chained matmuls with a compare fused in between:

    viol  = M @ (1 - L^T)           # MXU matmul 1: clause violation counts
    fired = (viol == 0) & nonempty  # VPU compare
    sums  = P @ fired               # MXU matmul 2: signed class popcount

The kernel tiles the flattened clause axis (grid dimension) so the include
matrix `M` streams HBM->VMEM one (TILE_C x 2F) block per step while the
literal block `L` stays VMEM-resident; class-sum partial products
accumulate in the output ref across grid steps (revisited block). See
DESIGN.md §6 / EXPERIMENTS.md §Perf for the VMEM/MXU accounting.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is estimated analytically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Tile sizes. The MXU is 128x128; the clause tile is the streamed axis.
TILE_C = 128
LANE = 128  # pad literal / class / batch axes to this multiple


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _kernel(l_ref, m_ref, p_ref, ne_ref, sums_ref, fired_ref):
    """One grid step: clause tile i.

    l_ref:  (2F~, B~)   literals, transposed+complemented outside: (1-L)^T
    m_ref:  (TILE_C, 2F~) include-mask tile
    p_ref:  (K~, TILE_C)  polarity tile
    ne_ref: (TILE_C, LANE) nonempty flags (broadcast along lanes)
    sums_ref:  (K~, B~)   accumulated class sums (revisited across steps)
    fired_ref: (TILE_C, B~) clause bits for this tile
    """
    i = pl.program_id(0)

    # MXU matmul 1: violation counts for this clause tile.
    viol = jnp.dot(m_ref[...], l_ref[...], preferred_element_type=jnp.float32)
    fired = jnp.where((viol == 0.0) & (ne_ref[:, :1] > 0.0), 1.0, 0.0)
    fired_ref[...] = fired

    # MXU matmul 2: partial signed popcount, accumulated over clause tiles.
    partial = jnp.dot(p_ref[...], fired, preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = partial

    @pl.when(i > 0)
    def _acc():
        sums_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("interpret", "tile_c"))
def _call(lits_nT, inc_p, pol_p, ne_p, interpret=True, tile_c=TILE_C):
    c_pad, lf = inc_p.shape
    k_pad = pol_p.shape[0]
    b_pad = lits_nT.shape[1]
    grid = (c_pad // tile_c,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((lf, b_pad), lambda i: (0, 0)),  # literals: resident
            pl.BlockSpec((tile_c, lf), lambda i: (i, 0)),  # M: streamed
            pl.BlockSpec((k_pad, tile_c), lambda i: (0, i)),  # P: streamed
            pl.BlockSpec((tile_c, LANE), lambda i: (i, 0)),  # nonempty
        ],
        out_specs=[
            pl.BlockSpec((k_pad, b_pad), lambda i: (0, 0)),  # sums: revisited
            pl.BlockSpec((tile_c, b_pad), lambda i: (i, 0)),  # fired: streamed
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k_pad, b_pad), jnp.float32),
            jax.ShapeDtypeStruct((c_pad, b_pad), jnp.float32),
        ],
        interpret=interpret,
    )(lits_nT, inc_p, pol_p, ne_p)


def clause_popcount(literals, include, polarity, nonempty, interpret: bool = True,
                    single_tile: bool = False):
    """Fused clause-eval + signed popcount via the Pallas kernel.

    Same contract as ref.clause_popcount_ref: returns (sums (B,K) i32,
    fired (B,C) i32). Pads every axis to MXU-friendly multiples, invokes the
    tiled kernel, and slices the padding back off.

    `single_tile=True` collapses the clause grid to one step. The multi-step
    grid is the *TPU* schedule (HBM→VMEM streaming of the include matrix,
    TILE_C = 128); under interpret=True the grid lowers to an XLA while-loop
    with dynamic-update-slices, which the CPU-AOT backend (xla_extension
    0.5.1) executes ~14× slower than the flat version — so the AOT export
    path flattens it (EXPERIMENTS.md §Perf L1/L2). The kernel body is
    identical either way, and tests pin both paths against the oracle.
    """
    b, lf = literals.shape
    c, lf2 = include.shape
    k = polarity.shape[0]
    assert lf == lf2, (lf, lf2)

    c_pad = _round_up(max(c, 1), TILE_C)
    lf_pad = _round_up(max(lf, 1), LANE)
    k_pad = _round_up(max(k, 1), 8)
    b_pad = _round_up(max(b, 1), 8)

    lits = jnp.zeros((b_pad, lf_pad), jnp.float32).at[:b, :lf].set(
        literals.astype(jnp.float32)
    )
    # Padded literal columns are 0 -> (1-L)=1 there; padded include rows are
    # all-zero so they contribute 0 violations, and padded *columns* of real
    # clauses are zero in M, so padding never changes viol.
    lits_nT = (1.0 - lits).T  # (2F~, B~); padded batch cols give viol>=0 but
    # their fired bits are sliced away below.

    inc_p = jnp.zeros((c_pad, lf_pad), jnp.float32).at[:c, :lf].set(
        include.astype(jnp.float32)
    )
    pol_p = jnp.zeros((k_pad, c_pad), jnp.float32).at[:k, :c].set(
        polarity.astype(jnp.float32)
    )
    ne_p = jnp.zeros((c_pad, LANE), jnp.float32).at[:c, :].set(
        nonempty.astype(jnp.float32)[:, None]
    )

    tile_c = c_pad if single_tile else TILE_C
    sums, fired = _call(lits_nT, inc_p, pol_p, ne_p, interpret=interpret, tile_c=tile_c)
    return (
        sums[:k, :b].T.astype(jnp.int32),
        fired[:c, :b].T.astype(jnp.int32),
    )


def vmem_report(n_classes: int, clauses_per_class: int, n_features: int, batch: int) -> dict:
    """Analytic VMEM/MXU accounting for the §Perf record (bytes, flops).

    interpret=True gives CPU-numpy wallclock, which is *not* a TPU proxy —
    this function derives the numbers DESIGN.md §6 asks for from the
    BlockSpecs instead.
    """
    c = n_classes * clauses_per_class
    lf = 2 * n_features
    c_pad, lf_pad = _round_up(c, TILE_C), _round_up(lf, LANE)
    k_pad, b_pad = _round_up(n_classes, 8), _round_up(batch, 8)
    f32 = 4
    vmem = {
        "literals_resident": lf_pad * b_pad * f32,
        "include_tile": TILE_C * lf_pad * f32,
        "polarity_tile": k_pad * TILE_C * f32,
        "nonempty_tile": TILE_C * LANE * f32,
        "sums_out": k_pad * b_pad * f32,
        "fired_tile": TILE_C * b_pad * f32,
    }
    total = sum(vmem.values())
    flops = 2 * c_pad * lf_pad * b_pad + 2 * k_pad * c_pad * b_pad
    hbm_bytes = (lf_pad * b_pad + c_pad * lf_pad + k_pad * c_pad + c_pad * LANE
                 + k_pad * b_pad + c_pad * b_pad) * f32
    return {
        "vmem_bytes": vmem,
        "vmem_total_bytes": total,
        "vmem_budget_bytes": 16 * 2**20,
        "fits_vmem": total <= 16 * 2**20,
        "grid_steps": c_pad // TILE_C,
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "arithmetic_intensity": flops / hbm_bytes,
    }
