"""Pure-jnp oracle for the fused clause-evaluation + signed-popcount kernel.

This is the L1 correctness reference: the Pallas kernel in
clause_popcount.py must match `clause_popcount_ref` bit-exactly (integer
semantics) for every shape/dtype the tests sweep.

Math (DESIGN.md §2 — the FPGA->TPU adaptation):

    violations = M @ (1 - L)        # (C, B)  M: include mask (C, 2F)
    fired      = (violations == 0) & nonempty
    sums       = P @ fired          # (K, B)  P: signed polarity (K, C)

where C = n_classes * clauses_per_class flattened class-major and P is the
block-diagonal ±1 vote matrix. `fired` is the per-clause bit vector the
hardware feeds into the PDLs; `sums` is the per-class popcount that the
time-domain argmax compares.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def clause_popcount_ref(literals, include, polarity, nonempty):
    """Reference implementation.

    Args:
      literals: (B, 2F) float or int — Boolean literals [x, ~x].
      include:  (C, 2F) — clause include masks, flattened class-major.
      polarity: (K, C)  — signed vote matrix (±1 within a class, 0 across).
      nonempty: (C,)    — 1 where the clause has at least one include.

    Returns:
      sums:   (B, K) int32 class sums.
      fired:  (B, C) int32 clause outputs.
    """
    lits = literals.astype(jnp.float32)
    inc = include.astype(jnp.float32)
    viol = inc @ (1.0 - lits).T  # (C, B)
    fired = jnp.where((viol == 0) & (nonempty.astype(jnp.float32)[:, None] > 0), 1.0, 0.0)
    sums = polarity.astype(jnp.float32) @ fired  # (K, B)
    return sums.T.astype(jnp.int32), fired.T.astype(jnp.int32)


def polarity_matrix(n_classes: int, clauses_per_class: int, polarity_flat) -> np.ndarray:
    """Build the (K, C) block-diagonal signed vote matrix from the per-clause
    ±1 vector (class-major flattening)."""
    c_total = n_classes * clauses_per_class
    pol = np.asarray(polarity_flat, dtype=np.float32).reshape(-1)
    assert pol.shape[0] == c_total
    P = np.zeros((n_classes, c_total), dtype=np.float32)
    for k in range(n_classes):
        lo = k * clauses_per_class
        P[k, lo : lo + clauses_per_class] = pol[lo : lo + clauses_per_class]
    return P


def tm_predict_ref(x_bool, include, polarity, nonempty):
    """End-to-end reference prediction: Booleans -> literals -> argmax."""
    lits = jnp.concatenate([x_bool, 1 - x_bool], axis=1)
    sums, fired = clause_popcount_ref(lits, include, polarity, nonempty)
    return jnp.argmax(sums, axis=1).astype(jnp.int32), sums, fired
