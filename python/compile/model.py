"""L2: the TM inference graph lowered to HLO for the Rust runtime.

`tm_forward` is the jitted function `aot.py` lowers per configuration and
batch size. It takes the Booleanized input batch and returns everything the
Rust coordinator needs:

  * `sums`  (B, K) i32 — per-class signed popcount (the quantity the paper's
    PDLs encode as delay),
  * `fired` (B, C) i32 — per-clause outputs (the bits the Rust substrate
    feeds into the simulated PDLs for per-sample latency),
  * `pred`  (B,)  i32 — argmax class (functional result).

Model parameters (include masks, polarity, nonempty flags) are *baked into
the HLO as constants*: the paper's hardware likewise bakes the trained
clauses into LUT configurations, and freezing them lets XLA fold the
violation matmul aggressively.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import clause_popcount as cp
from .kernels import ref


class TmParams:
    """Frozen trained-model tensors in the interchange layout."""

    def __init__(self, exported: dict):
        self.n_classes = int(exported["n_classes"])
        self.n_features = int(exported["n_features"])
        self.clauses_per_class = int(exported["clauses_per_class"])
        self.include = np.array(exported["include"], dtype=np.float32)
        self.nonempty = np.array(exported["nonempty"], dtype=np.float32)
        self.polarity_flat = np.array(exported["polarity"], dtype=np.float32)
        self.polarity = ref.polarity_matrix(
            self.n_classes, self.clauses_per_class, self.polarity_flat
        )

    @property
    def c_total(self) -> int:
        return self.n_classes * self.clauses_per_class


def make_forward(params: TmParams, use_pallas: bool = True, single_tile: bool = False):
    """Returns fwd(x_bool (B, F) f32) -> (sums, fired, pred).

    `single_tile` flattens the Pallas grid for the CPU-AOT export (see
    kernels/clause_popcount.py — the multi-step grid is the TPU schedule).
    """
    inc = jnp.asarray(params.include)
    pol = jnp.asarray(params.polarity)
    ne = jnp.asarray(params.nonempty)

    def fwd(x_bool):
        lits = jnp.concatenate([x_bool, 1.0 - x_bool], axis=1)
        if use_pallas:
            sums, fired = cp.clause_popcount(lits, inc, pol, ne, single_tile=single_tile)
        else:
            sums, fired = ref.clause_popcount_ref(lits, inc, pol, ne)
        pred = jnp.argmax(sums, axis=1).astype(jnp.int32)
        return (sums, fired, pred)

    return fwd


def lower_to_hlo_text(params: TmParams, batch: int, use_pallas: bool = True) -> str:
    """Lower the forward fn to HLO *text* (the interchange format the
    xla-0.1.6 crate can parse — serialized protos from jax>=0.5 carry 64-bit
    instruction ids that xla_extension 0.5.1 rejects)."""
    from jax._src.lib import xla_client as xc

    # single_tile: the AOT/CPU path flattens the Pallas grid (§Perf).
    fwd = make_forward(params, use_pallas=use_pallas, single_tile=True)
    spec = jax.ShapeDtypeStruct((batch, params.n_features), jnp.float32)
    lowered = jax.jit(fwd).lower(spec)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: the default printer elides big literals as `constant({...})`
    # — the trained include/polarity matrices! The xla text parser then
    # zero-fills them and the model silently computes garbage. Print with
    # large constants inlined (and assert none were elided).
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # xla_extension 0.5.1's parser predates newer metadata attributes
    # (source_end_line etc.) — strip metadata entirely.
    opts.print_metadata = False
    text = comp.as_hlo_module().to_string(opts)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text
