"""AOT build entry point: train TMs (cached), lower to HLO text, emit
metadata + golden vectors for the Rust side.

Run once via `make artifacts`; Python never executes on the request path.

Outputs under artifacts/:
  models/<name>.json          trained model (include masks as bitstrings)
  hlo/<name>_b<B>.hlo.txt     lowered HLO text per batch size
  golden/<name>.json          input/output vectors for Rust integration tests
  data/<name>_test.json       Booleanized test set for end-to-end runs
  manifest.json               index of everything above
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from . import model as model_mod
from .kernels import ref
from .tm import train as train_mod

BATCH_SIZES = (1, 32)


def bits_to_str(row) -> str:
    return "".join("1" if int(b) else "0" for b in row)


def encode_model(exported: dict) -> dict:
    """Compact the include matrix to per-clause bitstrings."""
    out = dict(exported)
    out["include"] = [bits_to_str(r) for r in exported["include"]]
    return out


def decode_model(doc: dict) -> dict:
    out = dict(doc)
    out["include"] = [[int(ch) for ch in row] for row in doc["include"]]
    return out


def train_or_load(name: str, art_dir: str, verbose: bool = True):
    cfg = train_mod.CONFIGS[name]
    path = os.path.join(art_dir, "models", f"{name}.json")
    if os.path.exists(path):
        with open(path) as f:
            doc = decode_model(json.load(f))
        if verbose:
            print(f"[aot] {name}: cached model (acc {doc['accuracy']:.1f}%)")
        return doc
    t0 = time.time()
    trained = train_mod.train(cfg, verbose=verbose)
    doc = trained.export()
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(encode_model(doc), f)
    if verbose:
        print(f"[aot] {name}: trained acc {doc['accuracy']:.1f}% "
              f"(paper {cfg.paper_accuracy}%) in {time.time() - t0:.0f}s")
    return doc


def emit_hlo(name: str, doc: dict, art_dir: str, verbose: bool = True) -> dict:
    params = model_mod.TmParams(doc)
    entries = {}
    os.makedirs(os.path.join(art_dir, "hlo"), exist_ok=True)
    for b in BATCH_SIZES:
        path = os.path.join(art_dir, "hlo", f"{name}_b{b}.hlo.txt")
        if not os.path.exists(path):
            text = model_mod.lower_to_hlo_text(params, b)
            with open(path, "w") as f:
                f.write(text)
            if verbose:
                print(f"[aot] {name}: wrote {path} ({len(text)} chars)")
        entries[str(b)] = os.path.relpath(path, art_dir)
    return entries


def emit_golden(name: str, doc: dict, art_dir: str, n_samples: int = 8) -> str:
    """Golden vectors from the *reference* path — the Rust integration tests
    assert the PJRT-executed HLO reproduces these bit-exactly."""
    params = model_mod.TmParams(doc)
    xb_tr, y_tr, xb_te, y_te, _ = train_mod.load_dataset(train_mod.CONFIGS[name])
    xs = xb_te[:n_samples].astype(np.float32)
    pred, sums, fired = ref.tm_predict_ref(
        xs, params.include, params.polarity, params.nonempty
    )
    doc_out = {
        "name": name,
        "n_samples": int(xs.shape[0]),
        "inputs": [bits_to_str(r) for r in xb_te[:n_samples]],
        "labels": [int(v) for v in y_te[:n_samples]],
        "sums": np.array(sums).tolist(),
        "fired": [bits_to_str(r) for r in np.array(fired)],
        "pred": np.array(pred).tolist(),
    }
    path = os.path.join(art_dir, "golden", f"{name}.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc_out, f)
    return os.path.relpath(path, art_dir)


def emit_test_data(name: str, art_dir: str, limit: int = 500) -> str:
    xb_tr, y_tr, xb_te, y_te, _ = train_mod.load_dataset(train_mod.CONFIGS[name])
    xb, y = xb_te[:limit], y_te[:limit]
    path = os.path.join(art_dir, "data", f"{name}_test.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(
            {
                "name": name,
                "n": int(xb.shape[0]),
                "n_features": int(xb.shape[1]),
                "x": [bits_to_str(r) for r in xb],
                "y": [int(v) for v in y],
            },
            f,
        )
    return os.path.relpath(path, art_dir)


def build(art_dir: str, configs=None, verbose: bool = True) -> dict:
    configs = configs or list(train_mod.CONFIGS)
    manifest = {"batch_sizes": list(BATCH_SIZES), "models": {}}
    for name in configs:
        cfg = train_mod.CONFIGS[name]
        doc = train_or_load(name, art_dir, verbose=verbose)
        hlo = emit_hlo(name, doc, art_dir, verbose=verbose)
        golden = emit_golden(name, doc, art_dir)
        data = emit_test_data(name, art_dir)
        manifest["models"][name] = {
            "dataset": cfg.dataset,
            "n_classes": cfg.n_classes,
            "n_features": cfg.n_features,
            "clauses_per_class": cfg.clauses_per_class,
            "T": cfg.T,
            "s": cfg.s,
            "accuracy": doc["accuracy"],
            "paper_accuracy": cfg.paper_accuracy,
            "model": f"models/{name}.json",
            "hlo": hlo,
            "golden": golden,
            "test_data": data,
        }
    with open(os.path.join(art_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        print(f"[aot] manifest written: {os.path.join(art_dir, 'manifest.json')}")
    return manifest


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--configs", nargs="*", default=None,
                    help="subset of configs (default: all)")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)
    build(os.path.abspath(args.out), args.configs, verbose=not args.quiet)
    return 0


if __name__ == "__main__":
    sys.exit(main())
